"""Jax-native inverse regularized incomplete beta (repro.core.betainc).

The §7.5 credible-bound fleet path stands on ``betaincinv`` agreeing with
``scipy.stats.beta.ppf``: the parity suite compares fleet decisions gated
on our inversion against the scalar executor gated on scipy's.  These
tests pin the agreement directly — a dense deterministic grid plus a
property-style sweep (mini-hypothesis shim when the real library is
absent) at <= 1e-10 relative error, and the scipy-documented special
values at the edges."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.experimental import enable_x64
from scipy import stats

from repro.core.batch_decision import batch_lower_bound
from repro.core.betainc import betaincinv

RTOL = 1e-10

# Deterministic acceptance grid: spans a/b << 1 through a/b >> 1 and deep
# tails of gamma; roots reach ~1e-160 without leaving float64 range.
GRID_AB = (0.05, 0.1, 0.3, 0.7, 1.0, 1.5, 4.0, 12.0, 40.0, 150.0)
GRID_Q = (1e-8, 1e-6, 1e-4, 1e-2, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99,
          1.0 - 1e-4, 1.0 - 1e-6)


def _rel_err(ours, ref):
    return np.abs(ours - ref) / np.maximum(np.abs(ref), 1e-300)


def test_grid_vs_scipy_ppf():
    """Full (alpha, beta, gamma) cross product against scipy.stats.beta.ppf
    at float64: <= 1e-10 relative error everywhere the root is nonzero.

    scipy's own iteration carries ~1e-10-scale error at a handful of
    points (e.g. a=b=0.3, q=0.5, whose exact root is 0.5 by symmetry —
    we return 0.5, scipy returns 0.5 + 2e-10); such points pass when our
    root round-trips through scipy's forward CDF at least as accurately
    as scipy's own root does."""
    with enable_x64():
        A, B, Q = np.meshgrid(GRID_AB, GRID_AB, GRID_Q, indexing="ij")
        ours = np.asarray(betaincinv(A, B, Q))
        ref = stats.beta.ppf(Q, A, B)
        assert np.all(np.isfinite(ours))
        rel = _rel_err(ours, ref)
        for i, j, k in np.argwhere(rel >= RTOL):
            a, b, q = A[i, j, k], B[i, j, k], Q[i, j, k]
            ours_rt = abs(stats.beta.cdf(ours[i, j, k], a, b) - q)
            ref_rt = abs(stats.beta.cdf(ref[i, j, k], a, b) - q)
            assert ours_rt <= ref_rt, (a, b, q, ours[i, j, k], ref[i, j, k])


def test_special_values_and_domain():
    with enable_x64():
        # scipy-compatible edges: q=0 -> 0, q=1 -> 1 exactly
        np.testing.assert_array_equal(
            np.asarray(betaincinv(2.0, 3.0, np.array([0.0, 1.0]))),
            [0.0, 1.0])
        # out-of-domain q and non-positive parameters -> NaN
        bad = np.asarray(betaincinv(
            np.array([2.0, 2.0, -1.0, 2.0]),
            np.array([3.0, 3.0, 3.0, 0.0]),
            np.array([-0.1, 1.5, 0.5, 0.5])))
        assert np.all(np.isnan(bad))


def test_tiny_shape_parameters_deep_tail():
    """a or b << 1 with tail gamma: the power-law initial guess must land
    the bracketed iteration on roots far below bisection reach."""
    with enable_x64():
        cases = [
            (0.05, 0.05, 1e-6), (0.05, 25.0, 1e-4), (0.1, 0.5, 1e-2),
            (25.0, 0.05, 1.0 - 1e-4), (0.5, 0.1, 1.0 - 1e-2),
            (0.02, 3.0, 0.3),
        ]
        for a, b, q in cases:
            ours = float(betaincinv(a, b, q))
            ref = float(stats.beta.ppf(q, a, b))
            assert _rel_err(ours, ref) < RTOL, (a, b, q, ours, ref)


@settings(max_examples=120, deadline=None)
@given(
    a=st.floats(min_value=0.05, max_value=80.0),
    b=st.floats(min_value=0.05, max_value=80.0),
    q=st.floats(min_value=1e-6, max_value=1.0 - 1e-6),
)
def test_property_matches_scipy_and_inverts_cdf(a, b, q):
    """Property sweep: betaincinv is scipy's quantile (<= 1e-10 rel) and a
    true right-inverse of the CDF wherever the draw lands."""
    with enable_x64():
        x = float(betaincinv(a, b, q))
        ref = float(stats.beta.ppf(q, a, b))
        assert 0.0 <= x <= 1.0
        assert _rel_err(np.asarray(x), np.asarray(ref)) < RTOL
        # round-trip through the forward CDF (scipy's, as the oracle)
        if 1e-300 < x < 1.0:
            assert abs(stats.beta.cdf(x, a, b) - q) < 1e-8


def test_monotone_in_q():
    """Quantiles are non-decreasing in gamma for fixed (a, b)."""
    with enable_x64():
        q = np.linspace(1e-6, 1.0 - 1e-6, 201)
        for a, b in [(0.3, 2.0), (5.0, 5.0), (0.1, 0.1), (40.0, 2.0)]:
            x = np.asarray(betaincinv(a, b, q))
            assert np.all(np.diff(x) >= 0.0)


def test_batch_lower_bound_matches_posterior_lower_bound():
    """batch_decision.batch_lower_bound == BetaPosterior.lower_bound
    (scipy) across a fleet of posterior parameters in one call."""
    from repro.core.posterior import beta_lower_bound

    with enable_x64():
        rng = np.random.default_rng(13)
        a = rng.uniform(0.2, 30.0, 256)
        b = rng.uniform(0.2, 30.0, 256)
        for gamma in (0.01, 0.1, 0.5):
            ours = batch_lower_bound(a, b, gamma)
            ref = np.array([beta_lower_bound(ai, bi, gamma)
                            for ai, bi in zip(a, b)])
            np.testing.assert_allclose(ours, ref, rtol=RTOL)


def test_float32_path_still_sane():
    """Without x64 the inversion runs at float32 (the _f convention);
    agreement degrades gracefully to f32-scale error, not garbage."""
    x = np.asarray(betaincinv(
        np.array([2.0, 0.5, 8.0]), np.array([3.0, 0.5, 1.0]), 0.1))
    ref = stats.beta.ppf(0.1, [2.0, 0.5, 8.0], [3.0, 0.5, 1.0])
    np.testing.assert_allclose(x, ref, rtol=5e-5)
