"""Online decision service (repro.core.online): batched per-tick decisions
must be bitwise-f64 equal to the scalar ``decision.evaluate`` (the
contraction-pinned D4 gate), posterior settlement must be bitwise the
``BetaPosterior.update`` recurrence, the in-graph kill-switch must match
``DriftMonitor.check_credible_bound`` step-for-step, and the §12.2–12.4
table-batched stages must match their scalar ``calibration`` twins on
identical logs (posteriors bitwise-f64, promotion/trigger flags exact)."""
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core.calibration import canary, online_calibration, shadow_mode
from repro.core.decision import Decision, DecisionInputs, evaluate
from repro.core.drift import DriftMonitor
from repro.core.online import (
    OnlineDecisionService,
    TELEMETRY_FIELDS,
    canary_batch,
    online_calibration_batch,
    shadow_mode_batch,
)
from repro.core.posterior import BetaPosterior
from repro.core.taxonomy import DependencyType
from repro.core.telemetry import SpeculationDecision, TelemetryLog
from repro.serving.spec_bridge import EngineOp, ThreadedSpeculativeRunner

# Established fleet tolerances: the §7.5 jax betaincinv differs from the
# scalar scipy ppf by <= 1e-10 relative, which spreads into EV; everything
# that does not depend on the quantile is bitwise (the online gate pins
# fp contraction, unlike the fleet engine's fused lowering).
LB_EV = dict(rtol=1e-8, atol=1e-14)


def _random_requests(rng, B, n_rows):
    return dict(
        rows=rng.integers(0, n_rows, B),
        alpha=rng.uniform(0, 1, B),
        lam=rng.uniform(1e-4, 0.5, B),
        lat=rng.uniform(0.01, 5.0, B),
        in_tok=rng.integers(1, 2000, B).astype(float),
        out_tok=rng.uniform(1, 2000, B),
        in_price=rng.uniform(1e-8, 1e-4, B),
        out_price=rng.uniform(1e-8, 1e-4, B),
    )


def _service(n_rows=6, **kw):
    svc = OnlineDecisionService(**kw)
    for i in range(n_rows):
        svc.register_edge(
            ("u", f"v{i}"),
            dep_type=DependencyType.ROUTER_K_WAY,
            k=2 + i % 5,
            discount=(0.95 if i % 3 == 0 else 1.0),
        )
    return svc


def _scalar_reference(svc, req, *, use_lower_bound=False, gamma=0.1):
    snap = svc.posterior_snapshot()
    out = []
    for i in range(len(req["rows"])):
        r = int(req["rows"][i])
        a, b = snap[r]
        post = BetaPosterior(alpha=float(a), beta=float(b))
        out.append(evaluate(
            DecisionInputs(
                P=post.mean,
                alpha=float(req["alpha"][i]),
                lambda_usd_per_s=float(req["lam"][i]),
                latency_seconds=float(req["lat"][i]),
                input_tokens=int(req["in_tok"][i]),
                output_tokens=float(req["out_tok"][i]),
                input_price=float(req["in_price"][i]),
                output_price=float(req["out_price"][i]),
                P_lower_bound=(post.lower_bound(gamma)
                               if use_lower_bound else None),
            ),
            use_lower_bound=use_lower_bound,
        ))
    return out


def _tick(svc, req, **kw):
    return svc.tick(
        req["rows"], alpha=req["alpha"], lambda_usd_per_s=req["lam"],
        latency_s=req["lat"], input_tokens=req["in_tok"],
        output_tokens=req["out_tok"], input_price=req["in_price"],
        output_price=req["out_price"], **kw)


# ---------------------------------------------------------------------------
# D4 gate parity (the tentpole contract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B", [1, 37, 301])
def test_tick_bitwise_equal_to_scalar_evaluate(B):
    """Batched mean-path decisions — flag, EV, threshold, margin — are
    bitwise-f64 equal to decision.evaluate on randomized inputs (the
    runtime-zero contraction pin; no FMA ULP allowance needed)."""
    with enable_x64():
        svc = _service()
        rng = np.random.default_rng(100 + B)
        req = _random_requests(rng, B, svc.n_rows)
        refs = _scalar_reference(svc, req)
        d = _tick(svc, req)
        for i, ref in enumerate(refs):
            assert bool(d.flag[i]) == (ref.decision is Decision.SPECULATE)
            assert d.EV_usd[i] == ref.EV_usd
            assert d.threshold_usd[i] == ref.threshold_usd
            assert d.margin_usd[i] == ref.margin_usd
            assert d.C_spec_usd[i] == ref.C_spec_usd
            assert d.P_used[i] == ref.P_used


def test_tick_lower_bound_parity():
    """§7.5 gating: decision flags match the scipy-backed scalar path; EV
    and P_used carry the established betaincinv-vs-ppf allowance; the
    threshold does not depend on the quantile and stays bitwise."""
    with enable_x64():
        svc = _service(use_lower_bound=True)
        rng = np.random.default_rng(5)
        req = _random_requests(rng, 128, svc.n_rows)
        refs = _scalar_reference(svc, req, use_lower_bound=True)
        d = _tick(svc, req)
        for i, ref in enumerate(refs):
            assert bool(d.flag[i]) == (ref.decision is Decision.SPECULATE)
            assert d.threshold_usd[i] == ref.threshold_usd
            np.testing.assert_allclose(d.P_used[i], ref.P_used, rtol=1e-9)
            np.testing.assert_allclose(d.EV_usd[i], ref.EV_usd, **LB_EV)


def test_tie_breaks_to_speculate():
    """EV == threshold exactly -> SPECULATE (§6.1), matching the scalar
    tie-break bitwise: zero prices make both sides +0.0."""
    with enable_x64():
        svc = _service()
        d = svc.tick([0], alpha=1.0, lambda_usd_per_s=0.0, latency_s=0.0,
                     input_tokens=0, output_tokens=0, input_price=0.0,
                     output_price=0.0)
        assert d.EV_usd[0] == 0.0 and d.threshold_usd[0] == 0.0
        assert bool(d.flag[0])


# ---------------------------------------------------------------------------
# spec_bridge routing (satellite: scalar path kept, parity pinned)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("use_lower_bound", [False, True])
def test_spec_bridge_service_route_matches_scalar(use_lower_bound):
    with enable_x64():
        svc = OnlineDecisionService()
        op = EngineOp("drafter", engine=None, max_new_tokens=160)
        routed = ThreadedSpeculativeRunner(
            lambda: (None, None), op, service=svc, edge=("clf", "drafter"))
        scalar = ThreadedSpeculativeRunner(lambda: (None, None), op)
        assert routed.service_row is not None
        rng = np.random.default_rng(17)
        for _ in range(100):
            post = BetaPosterior(alpha=float(rng.uniform(0.1, 40)),
                                 beta=float(rng.uniform(0.1, 40)))
            args = (post, float(rng.uniform(0, 1)),
                    float(rng.uniform(1e-3, 0.5)), float(rng.uniform(0.01, 5)))
            got = routed.decide_full(*args, use_lower_bound=use_lower_bound)
            ref = scalar.decide_full(*args, use_lower_bound=use_lower_bound)
            assert got.decision == ref.decision
            assert got.threshold_usd == ref.threshold_usd
            assert got.C_spec_usd == ref.C_spec_usd
            if use_lower_bound:
                np.testing.assert_allclose(got.EV_usd, ref.EV_usd, **LB_EV)
                np.testing.assert_allclose(
                    got.margin_usd, ref.margin_usd, rtol=1e-8, atol=1e-12)
            else:
                assert got.EV_usd == ref.EV_usd
                assert got.margin_usd == ref.margin_usd


def test_spec_bridge_reuses_registered_row():
    svc = OnlineDecisionService()
    op = EngineOp("drafter", engine=None)
    r1 = ThreadedSpeculativeRunner(lambda: (None, None), op, service=svc,
                                   edge=("clf", "drafter"))
    r2 = ThreadedSpeculativeRunner(lambda: (None, None), op, service=svc,
                                   edge=("clf", "drafter"))
    assert r1.service_row == r2.service_row
    r3 = ThreadedSpeculativeRunner(lambda: (None, None), op, service=svc,
                                   edge=("clf", "drafter"), tenant="acme")
    assert r3.service_row != r1.service_row
    r1.observe(True)
    svc.apply_outcomes()
    snap = svc.posterior_snapshot()
    assert snap[r1.service_row, 0] == pytest.approx(2.0)   # 1+1 successes
    # reusing a registered row with a different gamma would silently
    # diverge from the scalar §7.5 route -> must refuse loudly
    with pytest.raises(ValueError, match="gamma"):
        ThreadedSpeculativeRunner(lambda: (None, None), op, service=svc,
                                  edge=("clf", "drafter"), gamma=0.3)


# ---------------------------------------------------------------------------
# outcome settlement (discount recurrence)
# ---------------------------------------------------------------------------
def test_outcome_settlement_bitwise_matches_update_many():
    """Settled outcomes apply the exact BetaPosterior.update recurrence —
    bitwise at f64, including discount < 1 and repeated same-row outcomes
    within one tick (arrival order)."""
    with enable_x64():
        svc = _service(n_rows=4)
        rng = np.random.default_rng(9)
        refs = {r: svc.posterior(r) for r in range(4)}
        for _ in range(5):
            outs = [(int(rng.integers(0, 4)), bool(rng.integers(0, 2)))
                    for _ in range(int(rng.integers(1, 12)))]
            svc.apply_outcomes(outs)
            for r, s in outs:
                refs[r].update(s)
        snap = svc.posterior_snapshot()
        for r in range(4):
            assert snap[r, 0] == refs[r].alpha
            assert snap[r, 1] == refs[r].beta


def test_outcomes_settle_before_decisions():
    """Tick order contract: this tick's outcomes are visible to this
    tick's decisions (freshest-belief serving)."""
    with enable_x64():
        svc = _service(n_rows=1)
        ref = svc.posterior(0)
        ref.update(True)
        req = _random_requests(np.random.default_rng(2), 4, 1)
        d = _tick(svc, req, outcomes=[(0, True)])
        assert np.all(d.P_mean == ref.mean)


def test_observe_queue_and_bounds():
    svc = _service(n_rows=2)
    svc.observe(1, True)
    svc.apply_outcomes()
    assert svc.posterior_snapshot()[1, 0] > svc.posterior_snapshot()[0, 0]
    with pytest.raises(IndexError):
        svc.apply_outcomes([(7, True)])
    with pytest.raises(IndexError):
        svc.tick([99], alpha=0.5, lambda_usd_per_s=0.01, latency_s=1.0,
                 input_tokens=1, output_tokens=1, input_price=0.0,
                 output_price=0.0)


# ---------------------------------------------------------------------------
# drift / kill-switch
# ---------------------------------------------------------------------------
def test_drift_matches_scalar_monitor_and_gates_serving():
    """The in-graph trigger-2 step matches DriftMonitor.check_credible_bound
    tick-for-tick (run counts, trigger instant, reset-and-count-again), the
    kill-switch forces WAIT, and ingest_online_triggers folds the state
    back into a scalar monitor."""
    with enable_x64():
        svc = _service(n_rows=2, credible_consecutive_n=3)
        # re-register row 1 with a breaching floor
        svc2 = OnlineDecisionService(credible_consecutive_n=3)
        svc2.register_edge(("u", "v0"), dep_type=DependencyType.ROUTER_K_WAY, k=2)
        C, Lv, al = 0.01, 0.002, 0.5
        svc2.register_edge(("u", "v1"), dep_type=DependencyType.ROUTER_K_WAY,
                           k=5, floor_alpha=al, floor_C_spec_usd=C,
                           floor_L_value_usd=Lv)
        mon = DriftMonitor(credible_consecutive_n=3)
        post = BetaPosterior.from_dependency_type(
            DependencyType.ROUTER_K_WAY, k=5)
        sink = DriftMonitor(credible_consecutive_n=3)
        for t in range(7):
            d = svc2.tick([1], alpha=0.5, lambda_usd_per_s=0.01, latency_s=1.0,
                          input_tokens=10, output_tokens=10, input_price=1e-6,
                          output_price=1e-5, check_drift=True)
            ev = mon.check_credible_bound(("u", "v1"), post, al, C, Lv)
            assert bool(d.drift_triggered[1]) == (ev is not None)
            assert svc2.breach_runs()[1] == mon._credible_breach_run[("u", "v1")]
            assert bool(svc2.enabled_snapshot()[1]) == mon.edge_enabled(("u", "v1"))
            # untouched row 0 never ticks its run
            assert svc2.breach_runs()[0] == 0 and svc2.enabled_snapshot()[0]
            got = sink.ingest_online_triggers(
                [svc2.row_key(i) for i in range(2)],
                d.drift_triggered[:2], svc2.breach_runs())
            assert (len(got) == 1) == (ev is not None)
        assert not sink.edge_enabled(("u", "v1"))
        assert sink.state(("u", "v1")).needs_shadow_rerun
        # the killed row serves WAIT even on a clearly-positive gate
        res = svc2.decide(("u", "v1"), alpha=1.0, lambda_usd_per_s=10.0,
                          latency_s=10.0, input_tokens=1, output_tokens=1,
                          input_price=1e-9, output_price=1e-9)
        assert res.decision is Decision.WAIT and res.EV_usd > res.threshold_usd


# ---------------------------------------------------------------------------
# telemetry ring (D2: every decision logged in dollars, flushed per tick)
# ---------------------------------------------------------------------------
def test_telemetry_ring_rows_and_wraparound():
    with enable_x64():
        svc = _service(n_rows=3, telemetry_capacity=32)
        rng = np.random.default_rng(11)
        req = _random_requests(rng, 20, 3)
        d1 = _tick(svc, req)
        tb = svc.drain_telemetry()
        assert set(tb.fields) == set(TELEMETRY_FIELDS)
        assert len(tb) == 20 and tb.dropped == 0
        np.testing.assert_array_equal(tb.fields["EV_usd"], d1.EV_usd)
        np.testing.assert_array_equal(tb.fields["margin_usd"], d1.margin_usd)
        np.testing.assert_array_equal(tb.fields["row"].astype(int), req["rows"])
        np.testing.assert_array_equal(
            tb.fields["speculate"].astype(bool), d1.speculate)
        rows = tb.rows()
        assert rows[0]["EV_usd"] == float(d1.EV_usd[0])
        # overflow the 32-slot ring: 3 ticks x 20 rows (bucketed to 32
        # slots each), one drain -> only the last tick's rows survive,
        # the 40 evicted real rows are reported as dropped
        evs = [_tick(svc, req).EV_usd for _ in range(3)]
        tb = svc.drain_telemetry()
        assert len(tb) == 20 and tb.dropped == 40
        np.testing.assert_array_equal(tb.fields["EV_usd"], evs[-1])


# ---------------------------------------------------------------------------
# table growth, dtype switch, sharding fallback
# ---------------------------------------------------------------------------
def test_registry_growth_preserves_live_state():
    with enable_x64():
        svc = _service(n_rows=2)
        svc.apply_outcomes([(0, True), (1, False)])
        before = svc.posterior_snapshot()
        for i in range(40):                      # force a table growth
            svc.register_edge(("g", f"v{i}"),
                              dep_type=DependencyType.CONDITIONAL_OUTPUT)
        after = svc.posterior_snapshot()
        assert after.shape[0] == 42
        np.testing.assert_array_equal(after[:2], before)
        assert svc.state.post.shape[0] == 64     # power-of-two padding


def test_dtype_switch_rebuilds_state():
    svc = _service(n_rows=2)
    req = _random_requests(np.random.default_rng(0), 4, 2)
    _tick(svc, req)
    assert svc.state.post.dtype == np.float32
    with enable_x64():
        d = _tick(svc, req)
        assert svc.state.post.dtype == np.float64
        assert d.EV_usd.dtype == np.float64


def test_mesh_without_fleet_axis_falls_back_unsharded():
    with enable_x64():
        import jax

        mesh = jax.make_mesh((1,), ("model",))   # no "fleet" axis
        svc = _service(n_rows=3, mesh=mesh)
        base = _service(n_rows=3)
        rng = np.random.default_rng(21)
        req = _random_requests(rng, 16, 3)
        d1, d0 = _tick(svc, req), _tick(base, req)
        np.testing.assert_array_equal(d1.EV_usd, d0.EV_usd)
        np.testing.assert_array_equal(
            svc.posterior_snapshot(), base.posterior_snapshot())


def test_tick_packed_matches_validating_tick():
    """The zero-copy hot path (packed request block, the benchmarked
    entry point) answers identically to the validating tick(), including
    pending-outcome flushes and padding sentinels."""
    with enable_x64():
        a = _service(n_rows=4)
        b = _service(n_rows=4)
        rng = np.random.default_rng(23)
        for _ in range(3):
            B = int(rng.integers(1, 40))
            req = _random_requests(rng, B, 4)
            outs = [(int(r), bool(s)) for r, s in zip(
                rng.integers(0, 4, 3), rng.integers(0, 2, 3))]
            for r, s in outs:
                a.observe(r, s)
                b.observe(r, s)
            da = _tick(a, req, check_drift=True)
            Bp = max(1, 1 << (B - 1).bit_length())
            row = np.full(Bp, -1, np.int32)
            row[:B] = req["rows"]
            reqs = np.zeros((Bp, 7), np.float64)
            for j, key in enumerate(("alpha", "lam", "lat", "in_tok",
                                     "out_tok", "in_price", "out_price")):
                reqs[:B, j] = req[key]
            db = b.tick_packed(row, reqs, batch=B, check_drift=True)
            assert db.batch == B
            np.testing.assert_array_equal(da.EV_usd, db.EV_usd)
            np.testing.assert_array_equal(da.margin_usd, db.margin_usd)
            np.testing.assert_array_equal(da.speculate, db.speculate)
        np.testing.assert_array_equal(
            a.posterior_snapshot(), b.posterior_snapshot())
        np.testing.assert_array_equal(a.breach_runs(), b.breach_runs())
        # batch defaults to the valid (non-sentinel) count — padding
        # slots must never surface as decisions
        row = np.array([0, 1, -1, -1], np.int32)
        d = b.tick_packed(row, np.zeros((4, 7), np.float64))
        assert d.batch == 2 and d.speculate.shape == (2,)


def test_donated_state_matches_default():
    """Opt-in donation (the HBM double-buffer mode) is numerically
    invisible: identical decisions and posterior trajectories."""
    with enable_x64():
        a = _service(n_rows=3)
        b = _service(n_rows=3, donate=True)
        rng = np.random.default_rng(13)
        for _ in range(3):
            req = _random_requests(rng, 24, 3)
            outs = [(int(r), bool(s)) for r, s in zip(
                rng.integers(0, 3, 5), rng.integers(0, 2, 5))]
            da = _tick(a, req, outcomes=outs, check_drift=True)
            db = _tick(b, req, outcomes=outs, check_drift=True)
            np.testing.assert_array_equal(da.EV_usd, db.EV_usd)
            np.testing.assert_array_equal(da.speculate, db.speculate)
        np.testing.assert_array_equal(
            a.posterior_snapshot(), b.posterior_snapshot())
        np.testing.assert_array_equal(
            a.drain_telemetry().fields["margin_usd"],
            b.drain_telemetry().fields["margin_usd"])


def test_decide_posterior_sync_and_snapshot_roundtrip():
    with enable_x64():
        svc = _service(n_rows=2)
        post = BetaPosterior(alpha=3.25, beta=1.5)
        res = svc.decide(("u", "v1"), posterior=post, alpha=0.4,
                         lambda_usd_per_s=0.08, latency_s=0.9,
                         input_tokens=32, output_tokens=160,
                         input_price=3e-6, output_price=15e-6)
        ref = evaluate(DecisionInputs(
            P=post.mean, alpha=0.4, lambda_usd_per_s=0.08,
            latency_seconds=0.9, input_tokens=32, output_tokens=160,
            input_price=3e-6, output_price=15e-6))
        assert (res.decision, res.EV_usd, res.threshold_usd) == (
            ref.decision, ref.EV_usd, ref.threshold_usd)
        got = svc.posterior(svc.row_index(("u", "v1")))
        assert got.as_row() == post.as_row()
        with pytest.raises(ValueError):
            svc.set_posterior(0, -1.0, 2.0)


# ---------------------------------------------------------------------------
# §12.2–12.4 folded onto the table (acceptance: scalar-stage parity)
# ---------------------------------------------------------------------------
def test_shadow_mode_batch_matches_scalar():
    rng = np.random.default_rng(31)
    R = 6
    posts = [BetaPosterior.from_prior_mean(
        float(rng.uniform(0.2, 0.8)),
        discount=(0.95 if r % 2 else 1.0)) for r in range(R)]
    trials = [[("billing" if rng.random() < 0.6 else "support", "billing")
               for _ in range(int(rng.integers(1, 120)))] for _ in range(R)]
    graded = [[("same text", "same text" if rng.random() < 0.5 else "other",
                bool(rng.integers(0, 2)))
               for _ in range(int(rng.integers(0, 8)))] for _ in range(R)]
    toks = [[float(x) for x in rng.uniform(10, 300, int(rng.integers(0, 9)))]
            for _ in range(R)]
    cancels = [[float(x) for x in rng.uniform(0, 1, int(rng.integers(0, 5)))]
               for _ in range(R)]
    edges = [("u", f"v{r}") for r in range(R)]
    batch = shadow_mode_batch(
        edges, posts, trials, graded_subsets=graded,
        output_token_counts=toks, cancel_fractions=cancels,
        n_shadow=40, stability_window=20)
    for r in range(R):
        ref = shadow_mode(
            edges[r], posts[r], trials[r], graded_subset=graded[r],
            output_token_counts=toks[r], cancel_fractions=cancels[r],
            n_shadow=40, stability_window=20)
        got = batch[r]
        assert got.posterior.alpha == ref.posterior.alpha      # bitwise f64
        assert got.posterior.beta == ref.posterior.beta
        assert got.posterior.successes == ref.posterior.successes
        assert got.posterior.failures == ref.posterior.failures
        assert got.converged == ref.converged
        assert got.best_tier2_threshold == ref.best_tier2_threshold
        assert got.tier2_f1 == ref.tier2_f1
        assert got.token_estimator.ema == ref.token_estimator.ema
        assert got.token_estimator.cov == ref.token_estimator.cov
        assert got.rho_mean == ref.rho_mean
        # zero exposure: the caller's posterior was never touched
        assert posts[r].n == 0


def test_shadow_mode_batch_from_table_snapshot():
    """The service-table entry point: raw (R, 2) snapshot + discounts."""
    svc = _service(n_rows=3)
    snap = svc.posterior_snapshot()
    disc = [svc._rows[r].discount for r in range(3)]
    trials = [[("a", "a")] * 4, [("a", "b")] * 2, []]
    batch = shadow_mode_batch(
        [svc.row_key(r)[1] for r in range(3)], snap, trials, discounts=disc)
    for r in range(3):
        ref = shadow_mode(
            ("x", "y"),
            BetaPosterior(alpha=float(snap[r, 0]), beta=float(snap[r, 1]),
                          discount=disc[r]),
            trials[r])
        assert batch[r].posterior.alpha == ref.posterior.alpha
        assert batch[r].posterior.beta == ref.posterior.beta


def test_canary_batch_matches_scalar():
    rng = np.random.default_rng(33)
    R = 8
    alphas = (0.1, 0.3, 0.5, 0.9)
    sweeps = [{a: (float(rng.uniform(0.5, 2.0)), float(rng.uniform(0.01, 0.05)))
               for a in alphas} for _ in range(R)]
    P = rng.uniform(0.05, 0.95, R)
    C = rng.uniform(0.001, 0.02, R)
    L = rng.uniform(0.5, 4.0, R)
    lam_dec = rng.uniform(0.001, 0.2, R)
    ctrl_lat = rng.uniform(0.5, 3.0, R)
    ctrl_cost = rng.uniform(0.01, 0.06, R)
    chosen = [float(rng.choice(alphas)) for _ in range(R)]
    batch = canary_batch(ctrl_lat, ctrl_cost, sweeps, chosen, P, C, L,
                         lam_dec, budget_guardrail_usd=0.04)
    for r in range(R):
        ref = canary(ctrl_lat[r], ctrl_cost[r], sweeps[r], chosen[r],
                     P[r], C[r], L[r], lam_dec[r], budget_guardrail_usd=0.04)
        got = batch[r]
        assert got.lambda_implied == ref.lambda_implied        # bitwise f64
        assert got.audit == ref.audit
        assert got.promote == ref.promote
        assert got.pareto_alphas == ref.pareto_alphas
        assert [(a.name, a.alpha, a.latency_s, a.cost_usd) for a in got.arms] \
            == [(a.name, a.alpha, a.latency_s, a.cost_usd) for a in ref.arms]
    with pytest.raises(ValueError):
        canary_batch(ctrl_lat, ctrl_cost, sweeps, chosen, np.zeros(R), C, L,
                     lam_dec)


def _telemetry_row(P_mean, succ, committed, t3, gen, est):
    return SpeculationDecision(
        decision_id="x", trace_id="t", edge=("u", "v"),
        dep_type="router_k_way", tenant="d", model_version=("m", "1"),
        alpha=0.5, lambda_usd_per_s=0.01, P_mean=P_mean, P_lower_bound=None,
        C_spec_est_usd=0.01, L_est_s=1.0, input_tokens_est=10,
        output_tokens_est=est, input_price=1e-6, output_price=1e-5,
        EV_usd=0.0, threshold_usd=0.0, decision="SPECULATE", phase="runtime",
        overrode="none", i_hat_source="modal", uncertain_cost_flag=False,
        enabled=True, budget_remaining_usd=None, tier1_match=succ,
        tier2_match=None, tier3_accept=t3,
        tokens_generated_before_cancel=gen, committed_speculative=committed)


def test_online_calibration_batch_matches_scalar():
    rng = np.random.default_rng(37)
    n_rows, M = 4, 600
    logs = [TelemetryLog() for _ in range(n_rows)]
    cols = {k: [] for k in ("row", "P", "has", "succ", "comm", "t3s", "t3a",
                            "gen", "est")}
    for _ in range(M):
        r = int(rng.integers(0, n_rows))
        P = float(rng.uniform(0, 1))
        know = bool(rng.random() < 0.9)
        s = bool(rng.random() < P * 0.7)
        cm = bool(rng.integers(0, 2))
        sampled = bool(rng.random() < 0.3)
        acc = bool(rng.integers(0, 2))
        has_tok = bool(rng.random() < 0.7)
        g = float(rng.integers(1, 300)) if has_tok else np.nan
        e = int(rng.integers(1, 200))
        logs[r].emit(_telemetry_row(
            P, s if know else None, cm, acc if sampled else None,
            int(g) if has_tok else None, e))
        for k, v in zip(cols, (r, P, know, s, cm, sampled, acc, g, e)):
            cols[k].append(v)
    batch = online_calibration_batch(
        n_rows, cols["row"], cols["P"], cols["has"], cols["succ"],
        committed=cols["comm"], tier3_sampled=cols["t3s"],
        tier3_accept=cols["t3a"], tokens_generated=cols["gen"],
        output_tokens_est=cols["est"], quarters_since_lambda_refresh=1)
    for r in range(n_rows):
        ref = online_calibration(logs[r], quarters_since_lambda_refresh=1)
        got = batch[r]
        assert len(got.buckets) == len(ref.buckets)
        for gb, rb in zip(got.buckets, ref.buckets):
            assert gb.midpoint == rb.midpoint
            assert gb.empirical_rate == rb.empirical_rate      # bitwise
            assert gb.n == rb.n
            assert gb.within_ci == rb.within_ci
        assert got.monotonic_overprediction == ref.monotonic_overprediction
        assert got.tier2_false_accept_rate == ref.tier2_false_accept_rate
        assert got.tier2_needs_tightening == ref.tier2_needs_tightening
        assert got.token_cov == ref.token_cov                  # bitwise
        assert got.uncertain_cost == ref.uncertain_cost
        assert got.lambda_refresh_due == ref.lambda_refresh_due


def test_online_calibration_batch_empty_signals():
    rep = online_calibration_batch(2, [0], [0.55], [True], [True])[0]
    assert rep.tier2_false_accept_rate is None
    assert rep.token_cov is None and not rep.uncertain_cost
    assert not rep.lambda_refresh_due


# ---------------------------------------------------------------------------
# fused Pallas tick (kernels.online_tick behind use_fused_tick) and the
# empty-settle dispatch skip
# ---------------------------------------------------------------------------
def test_fused_tick_defaults_off_and_matches_default_bitwise():
    """The fused settle+gate+drift kernel is opt-in (flag defaults off)
    and, when on, is numerically invisible: every decision field, the
    posterior table, the telemetry ring and the drift counters match the
    default XLA tick bitwise-f64 across a mixed tick stream."""
    with enable_x64():
        a = _service(n_rows=8)
        assert a.use_fused_tick is False
        b = _service(n_rows=8, use_fused_tick=True)
        rng = np.random.default_rng(7)
        for t in range(4):
            req = _random_requests(rng, 24, 8)
            outs = [(int(r), bool(s)) for r, s in zip(
                rng.integers(0, 8, 5), rng.integers(0, 2, 5))]
            da = _tick(a, req, outcomes=outs, check_drift=(t % 2 == 1))
            db = _tick(b, req, outcomes=outs, check_drift=(t % 2 == 1))
            np.testing.assert_array_equal(da.EV_usd, db.EV_usd)
            np.testing.assert_array_equal(da.threshold_usd, db.threshold_usd)
            np.testing.assert_array_equal(da.margin_usd, db.margin_usd)
            np.testing.assert_array_equal(da.speculate, db.speculate)
            np.testing.assert_array_equal(
                da.drift_triggered, db.drift_triggered)
        np.testing.assert_array_equal(
            a.posterior_snapshot(), b.posterior_snapshot())
        np.testing.assert_array_equal(np.asarray(a._tel), np.asarray(b._tel))
        np.testing.assert_array_equal(a.breach_runs(), b.breach_runs())


def test_fused_tick_lower_bound_flags_match():
    """§7.5 tier through the fused kernel: flags must agree exactly; EV
    inherits only the in-kernel-betainc vs XLA-custom-call allowance."""
    with enable_x64():
        a = _service(n_rows=8)
        b = _service(n_rows=8, use_fused_tick=True)
        rng = np.random.default_rng(11)
        req = _random_requests(rng, 32, 8)
        da = _tick(a, req, use_lower_bound=True)
        db = _tick(b, req, use_lower_bound=True)
        np.testing.assert_array_equal(da.speculate, db.speculate)
        np.testing.assert_allclose(da.EV_usd, db.EV_usd, rtol=1e-9)


def test_fused_tick_rollout_falls_back_to_xla():
    """Rollout ticks aren't fused: a fused-enabled service must answer
    them through the default executable, identically to a default
    service (a silent fused dispatch would diverge or crash here)."""
    with enable_x64():
        a = _service(n_rows=4)
        b = _service(n_rows=4, use_fused_tick=True)
        rng = np.random.default_rng(5)
        row = (np.arange(8) % 4).astype(np.int32)
        reqs = np.zeros((8, 7), np.float64)
        reqs[:, 0] = rng.uniform(0, 1, 8)
        reqs[:, 1] = rng.uniform(1e-3, 0.5, 8)
        reqs[:, 2] = rng.uniform(0.05, 4.0, 8)
        reqs[:, 3], reqs[:, 4] = 32, 160
        reqs[:, 5], reqs[:, 6] = 3e-6, 15e-6
        da = a.tick_packed(row, reqs, use_rollout=True, check_drift=True)
        db = b.tick_packed(row, reqs, use_rollout=True, check_drift=True)
        np.testing.assert_array_equal(da.EV_usd, db.EV_usd)
        np.testing.assert_array_equal(da.speculate, db.speculate)
        np.testing.assert_array_equal(
            a.posterior_snapshot(), b.posterior_snapshot())


def test_empty_settle_bucket_skipped_at_dispatch():
    """An all-padding settle bucket is substituted with the S=0 bucket
    before dispatch (S is part of the trace key, so this skips a whole
    scan trace + its per-tick cost), counted, and bitwise invisible."""
    with enable_x64():
        a = _service(n_rows=4)
        b = _service(n_rows=4)
        rng = np.random.default_rng(3)
        row = (np.arange(8) % 4).astype(np.int32)
        reqs = np.zeros((8, 7), np.float64)
        reqs[:, 0] = rng.uniform(0, 1, 8)
        reqs[:, 1] = rng.uniform(1e-3, 0.5, 8)
        reqs[:, 2] = rng.uniform(0.05, 4.0, 8)
        reqs[:, 3], reqs[:, 4] = 32, 160
        reqs[:, 5], reqs[:, 6] = 3e-6, 15e-6
        pad_row = np.full(6, -1, np.int32)
        pad_x = np.zeros(6, np.float64)
        da = a.tick_packed(row, reqs, out_row=pad_row, out_x=pad_x)
        db = b.tick_packed(row, reqs)
        assert a.empty_settles_skipped == 1
        assert b.empty_settles_skipped == 0
        np.testing.assert_array_equal(da.EV_usd, db.EV_usd)
        np.testing.assert_array_equal(da.speculate, db.speculate)
        np.testing.assert_array_equal(
            a.posterior_snapshot(), b.posterior_snapshot())
        np.testing.assert_array_equal(np.asarray(a._tel), np.asarray(b._tel))
        # a bucket with any real outcome must still dispatch the settle
        real_row = np.array([0, -1, -1, -1], np.int32)
        real_x = np.array([1.0, 0.0, 0.0, 0.0])
        a.tick_packed(row, reqs, out_row=real_row, out_x=real_x)
        assert a.empty_settles_skipped == 1
