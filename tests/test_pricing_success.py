"""D2 pricing + §7.4 success-criterion + §3.3 admissibility tests."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.admissibility import AdmissibilityTag, CommitBarrier, check_admissible
from repro.core.pricing import (
    GpuHourCost,
    PricingEntry,
    TpuChipHourCost,
    TwoRateTokenCost,
    get_pricing,
    speculation_cost,
)
from repro.core.success import (
    TierPolicy,
    check_success,
    code_equivalent,
    json_equivalent,
    text_equivalent,
)


class TestPricing:
    def test_two_rate_worked_example(self):
        """§10.1: 500 in @ $3/M + 1000 out @ $15/M = $0.0165."""
        assert speculation_cost(500, 1000, 3e-6, 15e-6) == pytest.approx(0.0165)

    def test_autoreply(self):
        assert speculation_cost(500, 800, 3e-6, 15e-6) == pytest.approx(0.0135)

    def test_rate_asymmetry_range(self):
        """§4.1: major APIs bill output at 3-8x input."""
        for (prov, model) in [("anthropic", "claude-opus-4-7"),
                              ("openai", "gpt-5.2"), ("google", "gemini-3-pro")]:
            e = get_pricing(prov, model)
            assert 3.0 <= e.rate_asymmetry <= 8.0

    def test_gpu_hour_reduces_to_linear(self):
        """§4.3: GPU-hour amortization is linear per token."""
        cm = GpuHourCost(unit_price_per_hour=2.0, num_gpus=8,
                         decode_tokens_per_hour=3.6e6,
                         prefill_tokens_per_hour=36e6, utilization=0.8)
        c1 = cm.cost(100, 100)
        c2 = cm.cost(200, 200)
        assert c2 == pytest.approx(2 * c1)
        assert cm.cost(0, 0) == 0.0

    def test_tpu_chip_hour(self):
        cm = TpuChipHourCost(chip_price_per_hour=1.2, num_chips=4,
                             decode_tokens_per_hour=2e6,
                             prefill_tokens_per_hour=20e6)
        assert cm.cost(1000, 1000) > 0
        ci, co = cm.split(1000, 1000)
        assert co > ci  # decode slower than prefill -> output costlier

    @given(it=st.integers(0, 10**6), ot=st.integers(0, 10**6))
    @settings(max_examples=100, deadline=None)
    def test_split_sums_to_cost(self, it, ot):
        cm = TwoRateTokenCost(3e-6, 15e-6)
        ci, co = cm.split(it, ot)
        assert ci + co == pytest.approx(cm.cost(it, ot))

    def test_negative_rejected(self):
        with pytest.raises(ValueError):
            PricingEntry("x", "y", -1.0, 1.0)
        with pytest.raises(ValueError):
            TwoRateTokenCost(1e-6, 1e-6).cost(-1, 0)


class TestSuccessCriterion:
    def test_tier1_exact(self):
        r = check_success("billing", "billing")
        assert r.success and r.tier == 1 and r.tier1_match

    def test_tier2_text_paraphrase(self):
        r = check_success("the  Billing Issue", "the billing issue")
        assert r.success  # normalization catches case/whitespace

    def test_tier2_rejects_different(self):
        r = check_success("quantum entanglement basics",
                          "refund request for order 9")
        assert not r.success

    def test_tier2_code_ast(self):
        a = "def f(x):\n    return x+1"
        b = "def f(x):  return (x + 1)"
        assert code_equivalent(a, b)
        assert not code_equivalent(a, "def f(x):\n    return x+2")
        r = check_success(a, b, TierPolicy(domain="code"))
        assert r.success and r.tier == 2

    def test_tier2_semantic_json(self):
        assert json_equivalent('{"a": 1, "b": [2, 3]}', '{"b": [2, 3], "a": 1.0}')
        assert not json_equivalent('{"a": 1}', '{"a": 2}')
        r = check_success({"a": 1, "b": 2}, {"b": 2, "a": 1}, TierPolicy(domain="json"))
        assert r.success

    def test_tier3_opt_in(self):
        """Tier 3 is opt-in and only consulted when tiers 1/2 fail."""
        policy = TierPolicy(
            enable_tier3=True,
            tier3_validator=lambda i, downstream_out: downstream_out == "ok",
        )
        r = check_success("aaaa", "zzzz totally different", policy,
                          downstream_output_from_i_hat="ok")
        assert r.success and r.tier == 3
        r2 = check_success("aaaa", "zzzz totally different", policy,
                           downstream_output_from_i_hat="bad")
        assert not r2.success

    def test_threshold_tightening(self):
        """§12.2: higher threshold -> stricter acceptance."""
        loose = TierPolicy(similarity_threshold=0.5)
        tight = TierPolicy(similarity_threshold=0.999)
        a, b = "refund the customer order", "refund customer order now"
        assert check_success(a, b, loose).success
        assert not check_success(a, b, tight).success or a == b

    @given(st.text(max_size=80))
    @settings(max_examples=100, deadline=None)
    def test_tier2_reflexive(self, s):
        assert text_equivalent(s, s)


class TestAdmissibility:
    def test_only_non_speculable_blocked(self):
        assert check_admissible(AdmissibilityTag.SIDE_EFFECT_FREE)
        assert check_admissible(AdmissibilityTag.IDEMPOTENT)
        assert check_admissible(AdmissibilityTag.COMMIT_BARRIER)
        assert not check_admissible(AdmissibilityTag.NON_SPECULABLE)

    def test_commit_barrier_lifecycle(self):
        sent = []
        b = CommitBarrier(release=sent.append)
        b.stage("email-1")
        b.stage("email-2")
        assert b.pending == 2
        assert b.commit() == 2
        assert sent == ["email-1", "email-2"]
        with pytest.raises(RuntimeError):
            b.drop()

    def test_commit_barrier_drop(self):
        sent = []
        b = CommitBarrier(release=sent.append)
        b.stage("email-1")
        assert b.drop() == 1
        assert sent == []           # nothing escaped
        with pytest.raises(RuntimeError):
            b.commit()
