"""Per-architecture smoke tests: REDUCED same-family configs, one forward /
train step on CPU asserting output shapes + no NaNs, plus a prefill+decode
consistency check.  (Full configs are exercised only via the dry-run.)"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY
from repro.models import build_model

pytestmark = pytest.mark.slow  # interpreter-mode model steps, minutes on CPU

ARCHS = sorted(REGISTRY)


def make_batch(cfg, B=2, S=48, key=1):
    kd = (B, S, cfg.num_codebooks) if cfg.num_codebooks > 1 else (B, S)
    batch = {"tokens": jax.random.randint(jax.random.key(key), kd, 0,
                                          cfg.vocab_size)}
    if cfg.vision_tokens:
        batch["vision_embeds"] = 0.01 * jnp.ones((B, cfg.vision_tokens,
                                                  cfg.d_model), jnp.float32)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None, :], (3, B, S)).astype(jnp.int32)
    return batch


@pytest.fixture(scope="module")
def smoke(request):
    return None


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = REGISTRY[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg)
    loss, metrics = model.loss(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    logits = model.logits(params, batch)
    want = (2, 48, cfg.num_codebooks, cfg.vocab_size) if cfg.num_codebooks > 1 \
        else (2, 48, cfg.vocab_size)
    assert logits.shape == want
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    """One SGD step moves the loss (grads flow through every block)."""
    cfg = REGISTRY[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, S=32)

    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss0, grads = jax.value_and_grad(loss_fn)(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0, f"{arch}: dead gradients"
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype), params, grads)
    loss1 = loss_fn(params2)
    assert jnp.isfinite(loss1)
    assert float(loss1) < float(loss0), f"{arch}: step did not descend"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Prefill + per-token decode reproduces the full forward logits."""
    cfg = REGISTRY[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = make_batch(cfg, B=B, S=S, key=2)
    if cfg.vision_tokens:   # decode path: drop frontend stub for simplicity
        batch.pop("positions")
        full = model.logits(params, batch)
    else:
        full = model.logits(params, batch)
    cache = model.init_cache(B, 32, dtype=jnp.float32)
    prefix = {k: (v[:, : S - 2] if k == "tokens" else v) for k, v in batch.items()}
    _, cache = model.prefill(params, prefix, cache)
    errs = []
    for t in range(S - 2, S):
        tok = batch["tokens"][:, t][:, None] if cfg.num_codebooks == 1 \
            else batch["tokens"][:, t][:, None, :]
        logits, cache = model.decode_step(
            params, tok, cache, jnp.full((B,), t, jnp.int32))
        errs.append(float(jnp.abs(full[:, t : t + 1] - logits).max()))
    assert max(errs) < 2e-4, f"{arch}: decode diverges {errs}"


def test_full_configs_match_assignment():
    """The registry carries the exact assigned hyperparameters."""
    spec = {
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
    }
    for arch, (L, d, H, kv, ff, V) in spec.items():
        c = REGISTRY[arch]
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, H, kv, ff, V), arch
    # MoE specifics
    assert REGISTRY["arctic-480b"].moe.num_experts == 128
    assert REGISTRY["arctic-480b"].moe.top_k == 2
    assert REGISTRY["arctic-480b"].moe.dense_residual
    ds = REGISTRY["deepseek-v3-671b"]
    assert ds.moe.num_experts == 256 and ds.moe.top_k == 8
    assert ds.moe.num_shared_experts == 1 and ds.attn_type == "mla"
    assert ds.mtp_depth == 1
    assert REGISTRY["mamba2-1.3b"].ssm.d_state == 128
    assert REGISTRY["recurrentgemma-9b"].layer_pattern == ("rglru", "rglru", "attn")
    assert REGISTRY["musicgen-medium"].num_codebooks == 4
    # sub-quadratic flags drive the long_500k skip table (DESIGN.md §5)
    assert REGISTRY["mamba2-1.3b"].sub_quadratic
    assert REGISTRY["recurrentgemma-9b"].sub_quadratic
    assert sum(c.sub_quadratic for c in REGISTRY.values()) == 2


def test_param_counts_near_names():
    """Parameter counts land near the model names."""
    expect = {
        "qwen2-vl-72b": 72.7e9, "llama3.2-1b": 1.24e9, "yi-34b": 34.4e9,
        "qwen2.5-32b": 32.8e9, "arctic-480b": 477e9,
        "deepseek-v3-671b": 671e9, "recurrentgemma-9b": 8.5e9,
        "musicgen-medium": 1.8e9, "mamba2-1.3b": 1.34e9,
    }
    for arch, n in expect.items():
        got = REGISTRY[arch].param_count()
        assert abs(got - n) / n < 0.15, f"{arch}: {got/1e9:.1f}B vs {n/1e9:.1f}B"
