"""§6.6 routing, batch-vs-scalar decision equivalence, archetype rubric,
streaming re-estimator, and the serving engine + bridge."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.archetypes import ARCHETYPES, NON_FIT_SHAPES, fit_rubric, pilot_score
from repro.core.batch_decision import (
    batch_evaluate,
    batch_implied_lambda,
    counterfactual_grid,
    critical_k_grid,
)
from repro.core.decision import (
    Decision,
    DecisionInputs,
    critical_k,
    evaluate,
    implied_lambda,
    speculation_decision,
)
from repro.core.router import RouteCandidate, route
from repro.core.streaming import (
    ChunkVerdict,
    RhoEstimator,
    StreamingReestimator,
    expected_speculation_waste,
    fractional_waste,
)
from repro.core.pricing import TwoRateTokenCost


class TestRouter:
    def _candidates(self):
        return [
            RouteCandidate("anthropic", "claude-opus-4-7", 1.0, 800, 500, 0.8),
            RouteCandidate("anthropic", "claude-haiku-4-5", 2.5, 800, 500, 0.7),
        ]

    def test_latency_sensitive_picks_fast_tier(self):
        choice = route(self._candidates(), alpha=1.0, lambda_usd_per_s=0.1)
        assert choice.candidate.model == "claude-opus-4-7"

    def test_cost_sensitive_picks_cheap_tier(self):
        choice = route(self._candidates(), alpha=0.0, lambda_usd_per_s=0.1)
        assert choice.candidate.model == "claude-haiku-4-5"

    def test_empty_rejected(self):
        with pytest.raises(ValueError):
            route([], 0.5, 0.01)


class TestBatchEquivalence:
    @given(st.lists(st.floats(0.01, 0.99), min_size=1, max_size=50),
           st.floats(0, 1))
    @settings(max_examples=50, deadline=None)
    def test_batch_matches_scalar(self, Ps, alpha):
        """The JAX fast path and the §6.5 scalar path agree exactly."""
        _, _, spec_mask, _, _ = batch_evaluate(
            np.array(Ps), alpha, 0.08, 0.8, 500, 800, 3e-6, 15e-6)
        for p, m in zip(Ps, np.asarray(spec_mask)):
            want = speculation_decision(p, alpha, 0.08, 500, 800, 3e-6, 15e-6, 0.8)
            assert (want == "SPECULATE") == bool(m)

    def test_critical_k_grid_matches_scalar(self):
        alphas = np.linspace(0, 1, 11)
        grid = critical_k_grid(0.064, 0.0135, alphas)
        for a, k in zip(alphas, grid):
            assert k == pytest.approx(critical_k(0.064, 0.0135, float(a)), rel=1e-5)

    def test_implied_lambda_batch(self):
        out = batch_implied_lambda([0.62, 0.62], 0.0135, [0.5, 0.9], 0.8)
        assert out[0] == pytest.approx(implied_lambda(0.62, 0.0135, 0.5, 0.8), rel=1e-5)
        assert out[1] == pytest.approx(implied_lambda(0.62, 0.0135, 0.9, 0.8), rel=1e-5)

    def test_grid_shapes(self):
        g = counterfactual_grid(0.7, np.ones(100), np.full(100, 0.0135),
                                [0, 0.5, 1.0], [0.01, 0.05])
        assert g["speculate_fraction"].shape == (3, 2)
        # more latency-sensitive alpha never speculates less
        sf = g["speculate_fraction"]
        assert (np.diff(sf, axis=0) >= -1e-9).all()


class TestStreaming:
    def test_fractional_waste_monotone(self):
        cm = TwoRateTokenCost(3e-6, 15e-6)
        w = [fractional_waste(cm, 500, 1000, f * 1000) for f in (0.0, 0.3, 1.0)]
        assert w[0] == pytest.approx(0.0015)     # input only
        assert w[0] < w[1] < w[2] == pytest.approx(0.0165)

    def test_expected_waste_non_streaming_full(self):
        """§14.1: no streaming -> full C_spec accounting (rho=1)."""
        cm = TwoRateTokenCost(3e-6, 15e-6)
        full = expected_speculation_waste(0.6, cm, 500, 1000, rho=0.3,
                                          streaming=False)
        assert full == pytest.approx(0.4 * 0.0165)

    def test_rho_estimator_ema(self):
        r = RhoEstimator()
        assert r.rho == 0.5                       # §9.3 default
        r.observe(0.2)
        assert r.rho == pytest.approx(0.2)
        r.observe(0.6)
        assert r.rho == pytest.approx(0.2 * 0.6 + 0.8 * 0.2)

    def test_reestimator_cancels_on_confidence_collapse(self):
        base = DecisionInputs(P=0.7, alpha=0.5, lambda_usd_per_s=0.08,
                              latency_seconds=0.8, input_tokens=500,
                              output_tokens=800, input_price=3e-6,
                              output_price=15e-6)
        confs = [0.8, 0.75, 0.7, 0.05, 0.05]

        def refine(upstream_input, partial):
            return "billing", confs[len(partial) - 1]

        re = StreamingReestimator(refine, base)
        verdict, all_v = re.run("email", ["c0", "c1", "c2", "c3", "c4"])
        assert verdict is not None and verdict.cancel
        assert verdict.chunk_index == 3
        assert len(all_v) == 4                    # stopped at the cancel

    def test_throttling(self):
        base = DecisionInputs(P=0.7, alpha=0.5, lambda_usd_per_s=0.08,
                              latency_seconds=0.8, input_tokens=500,
                              output_tokens=800, input_price=3e-6,
                              output_price=15e-6)
        calls = []

        def refine(u, partial):
            calls.append(len(partial))
            return "x", 0.9

        re = StreamingReestimator(refine, base, throttle_every=3)
        re.run("email", [f"c{i}" for i in range(9)])
        assert calls == [1, 4, 7]                 # every 3rd chunk (§9.1)


class TestArchetypes:
    def test_all_eight_fit(self):
        assert len(ARCHETYPES) == 8
        for a in ARCHETYPES.values():
            assert fit_rubric(a.profile()).fits, a.name

    def test_non_fit_shapes_documented(self):
        assert set(NON_FIT_SHAPES) == {
            "open_ended_creative", "runtime_determined_topology",
            "high_k_flat", "cheap_downstream",
        }

    def test_pilot_scoring_ranks_first_tier_high(self):
        """§13.4: voice-bot / moderation score 4/4."""
        assert pilot_score(ARCHETYPES["voice_bot_ivr"].profile()) == 4
        assert pilot_score(ARCHETYPES["content_moderation"].profile()) == 4


class TestServingBridge:
    @pytest.fixture(scope="class")
    def engine(self):
        from repro.configs import REGISTRY
        from repro.serving import EngineConfig, ServingEngine
        cfg = REGISTRY["llama3.2-1b"].reduced()
        return ServingEngine(cfg, cfg=EngineConfig(max_seq=96, decode_chunk=4))

    def test_generate_deterministic(self, engine):
        r1 = engine.generate([5, 6, 7], 12)
        r2 = engine.generate([5, 6, 7], 12)
        assert r1.tokens == r2.tokens
        assert len(r1.tokens) <= 12

    def test_mid_stream_cancellation(self, engine):
        import threading
        ev = threading.Event()
        ev.set()  # cancel at the first check
        r = engine.generate([5, 6, 7], 32, cancel_event=ev)
        assert r.cancelled
        assert r.tokens_generated < 32            # stopped early

    def test_threaded_speculation_commits_on_match(self, engine):
        from repro.serving import EngineOp, ThreadedSpeculativeRunner
        op = EngineOp("drafter", engine, max_new_tokens=8)

        def upstream():
            return "billing", None

        runner = ThreadedSpeculativeRunner(upstream, op)
        spec = runner.run_speculative("billing")
        assert spec.committed and spec.waste_usd == 0.0
        spec2 = runner.run_speculative("a completely different long intent zz")
        assert not spec2.committed and spec2.waste_usd > 0.0
