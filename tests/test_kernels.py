"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True on CPU; same kernels compile natively on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

pytestmark = pytest.mark.slow  # interpret=True Pallas sweeps

from repro.kernels import (
    decode_attention_op,
    flash_attention,
    rglru_scan_op,
    ssd_scan_op,
)
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ref import (
    reference_attention,
    reference_decode_attention,
    reference_rglru_scan,
    reference_ssd_scan,
)

TOL = dict(atol=2e-2, rtol=2e-2)      # bf16 sweeps
TOL32 = dict(atol=2e-5, rtol=2e-5)    # f32 sweeps


def tols(dtype):
    return TOL if dtype == jnp.bfloat16 else TOL32


class TestFlashAttention:
    @pytest.mark.parametrize("S,H,Hkv,D", [
        (128, 4, 4, 64),     # MHA
        (256, 8, 2, 64),     # GQA 4:1
        (192, 8, 1, 32),     # MQA, ragged seq (pads)
        (256, 4, 4, 128),    # wider head
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_sweep(self, S, H, Hkv, D, dtype):
        q = jax.random.normal(jax.random.key(1), (2, S, H, D), dtype)
        k = jax.random.normal(jax.random.key(2), (2, S, Hkv, D), dtype)
        v = jax.random.normal(jax.random.key(3), (2, S, Hkv, D), dtype)
        out = flash_attention_fwd(q, k, v, block_q=64, block_k=64, interpret=True)
        ref = reference_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), **tols(dtype))

    @pytest.mark.parametrize("window", [16, 64])
    def test_sliding_window(self, window):
        q = jax.random.normal(jax.random.key(1), (1, 256, 4, 32))
        k = jax.random.normal(jax.random.key(2), (1, 256, 1, 32))
        v = jax.random.normal(jax.random.key(3), (1, 256, 1, 32))
        out = flash_attention_fwd(q, k, v, window=window, block_q=64,
                                  block_k=64, interpret=True)
        ref = reference_attention(q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL32)

    def test_custom_vjp_matches_reference_grad(self):
        q = jax.random.normal(jax.random.key(1), (1, 64, 2, 32))
        k = jax.random.normal(jax.random.key(2), (1, 64, 2, 32))
        v = jax.random.normal(jax.random.key(3), (1, 64, 2, 32))
        g1 = jax.grad(lambda q: flash_attention(q, k, v).sum())(q)
        g2 = jax.grad(lambda q: reference_attention(q, k, v).sum())(q)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), **TOL32)


class TestDecodeAttention:
    @pytest.mark.parametrize("C,H,Hkv,D", [
        (96, 8, 2, 64), (128, 4, 1, 32), (100, 4, 4, 64),
    ])
    def test_partial_cache_and_masks(self, C, H, Hkv, D):
        B = 2
        q = jax.random.normal(jax.random.key(1), (B, H, D))
        kc = jax.random.normal(jax.random.key(2), (B, C, Hkv, D))
        vc = jax.random.normal(jax.random.key(3), (B, C, Hkv, D))
        pos = jnp.broadcast_to(jnp.arange(C)[None], (B, C)).astype(jnp.int32)
        pos = pos.at[:, int(0.8 * C):].set(-1)
        cur = jnp.array([int(0.5 * C), int(0.7 * C)], jnp.int32)
        out = decode_attention_op(q, kc, vc, pos, cur)
        ref = reference_decode_attention(q, kc, vc, pos, cur)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL32)

    def test_window_masking(self):
        B, C, H, D = 1, 64, 2, 32
        q = jax.random.normal(jax.random.key(1), (B, H, D))
        kc = jax.random.normal(jax.random.key(2), (B, C, 1, D))
        vc = jax.random.normal(jax.random.key(3), (B, C, 1, D))
        pos = jnp.arange(C)[None].astype(jnp.int32)
        cur = jnp.array([60], jnp.int32)
        from repro.kernels.decode_attention import decode_attention_kernel_call
        out = decode_attention_kernel_call(q, kc, vc, pos, cur, window=16,
                                           interpret=True)
        ref = reference_decode_attention(q, kc, vc, pos, cur, window=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL32)


class TestRglruScan:
    @pytest.mark.parametrize("B,T,C", [(2, 200, 96), (1, 64, 128), (3, 130, 64)])
    def test_sweep(self, B, T, C):
        a = jax.nn.sigmoid(jax.random.normal(jax.random.key(4), (B, T, C)))
        b = jax.random.normal(jax.random.key(5), (B, T, C))
        h0 = jax.random.normal(jax.random.key(6), (B, C))
        out = rglru_scan_op(a, b, h0)
        ref = reference_rglru_scan(a, b, h0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)

    def test_zero_state_start(self):
        a = jnp.full((1, 32, 16), 0.5)
        b = jnp.ones((1, 32, 16))
        out = rglru_scan_op(a, b, None)
        ref = reference_rglru_scan(a, b, None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


class TestSsdScan:
    @pytest.mark.parametrize("S,H,P,N,chunk", [
        (96, 4, 16, 32, 32), (128, 2, 32, 16, 64), (100, 4, 16, 32, 32),
    ])
    def test_sweep(self, S, H, P, N, chunk):
        B = 2
        x = jax.random.normal(jax.random.key(7), (B, S, H, P)) * 0.5
        A = -jnp.abs(jax.random.normal(jax.random.key(8), (B, S, H))) * 0.1
        Bm = jax.random.normal(jax.random.key(9), (B, S, N)) * 0.5
        Cm = jax.random.normal(jax.random.key(10), (B, S, N)) * 0.5
        y = ssd_scan_op(x, A, Bm, Cm, chunk=chunk)
        yref, _ = reference_ssd_scan(x, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   atol=1e-4, rtol=1e-4)

    def test_matches_model_ssd_chunked(self):
        """Kernel == the model's chunked SSD (same math, different tiling)."""
        from repro.models.ssd import ssd_chunked
        B, S, H, P, N = 1, 64, 2, 16, 32
        x = jax.random.normal(jax.random.key(7), (B, S, H, P)) * 0.5
        A = -jnp.abs(jax.random.normal(jax.random.key(8), (B, S, H))) * 0.1
        Bm = jax.random.normal(jax.random.key(9), (B, S, N)) * 0.5
        Cm = jax.random.normal(jax.random.key(10), (B, S, N)) * 0.5
        y_kernel = ssd_scan_op(x, A, Bm, Cm, chunk=32)
        y_model, _ = ssd_chunked(x, A, Bm[:, :, None, :], Cm[:, :, None, :], 32)
        np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                                   atol=1e-4, rtol=1e-4)


RTOL_BII = 1e-10   # the established betaincinv tier (tests/test_betaincinv.py)


class TestBetaincinvPallas:
    """Tiled Pallas betaincinv vs the `jax.scipy`-based core path and
    scipy's ppf: <= 1e-10 relative on the acceptance grid (asserted in
    interpret mode — the gate every BENCH_kernels.json timing row sits
    behind), with the round-trip fallback for the handful of points where
    scipy's own iteration carries ~1e-10-scale error."""

    def test_grid_vs_core_and_scipy(self):
        from scipy import stats
        from repro.core.betainc import betaincinv
        from repro.kernels.betaincinv_pallas import betaincinv_kernel_call
        from test_betaincinv import GRID_AB, GRID_Q

        with enable_x64():
            A, B, Q = np.meshgrid(GRID_AB, GRID_AB, GRID_Q, indexing="ij")
            a, b, q = A.ravel(), B.ravel(), Q.ravel()
            ours = np.asarray(betaincinv_kernel_call(
                jnp.asarray(a), jnp.asarray(b), jnp.asarray(q),
                interpret=True))
            assert np.all(np.isfinite(ours))
            core = np.asarray(betaincinv(a, b, q))
            rel_core = np.abs(ours - core) / np.maximum(np.abs(core), 1e-300)
            assert rel_core.max() < RTOL_BII, rel_core.max()
            ref = stats.beta.ppf(q, a, b)
            rel = np.abs(ours - ref) / np.maximum(np.abs(ref), 1e-300)
            for (i,) in np.argwhere(rel >= RTOL_BII):
                ours_rt = abs(stats.beta.cdf(ours[i], a[i], b[i]) - q[i])
                ref_rt = abs(stats.beta.cdf(ref[i], a[i], b[i]) - q[i])
                assert ours_rt <= ref_rt, (a[i], b[i], q[i], ours[i], ref[i])

    def test_deep_tail_small_shape_parameters(self):
        """a, b << 1 with tail q: the in-kernel Lanczos lgamma (evaluated
        at z+1, stepped down) must keep the power-law initial guess and
        the bracketed iteration accurate at roots ~1e-160."""
        from scipy import stats
        from repro.kernels.betaincinv_pallas import betaincinv_kernel_call

        with enable_x64():
            cases = np.array([
                (0.05, 0.05, 1e-6), (0.05, 25.0, 1e-4), (0.1, 0.5, 1e-2),
                (25.0, 0.05, 1.0 - 1e-4), (0.5, 0.1, 1.0 - 1e-2),
                (0.02, 3.0, 0.3),
            ])
            a, b, q = cases.T
            ours = np.asarray(betaincinv_kernel_call(
                jnp.asarray(a), jnp.asarray(b), jnp.asarray(q),
                interpret=True))
            ref = stats.beta.ppf(q, a, b)
            rel = np.abs(ours - ref) / np.maximum(np.abs(ref), 1e-300)
            assert rel.max() < RTOL_BII, list(zip(cases, ours, ref))

    @pytest.mark.parametrize("n,block_n", [(7, 4), (16, 16), (33, 8),
                                           (5, 1024)])
    def test_tiling_and_padding_inert(self, n, block_n):
        """Any (n, block_n) tiling — ragged tiles padded with inert
        (a=1, b=1, q=0.5) lanes — returns exactly the untiled result."""
        from repro.kernels.betaincinv_pallas import betaincinv_kernel_call

        with enable_x64():
            rng = np.random.default_rng(n * 31 + block_n)
            a = jnp.asarray(rng.uniform(0.1, 40.0, n))
            b = jnp.asarray(rng.uniform(0.1, 40.0, n))
            q = jnp.asarray(rng.uniform(1e-6, 1 - 1e-6, n))
            tiled = betaincinv_kernel_call(a, b, q, block_n=block_n,
                                           interpret=True)
            whole = betaincinv_kernel_call(a, b, q, block_n=max(n, 1),
                                           interpret=True)
            np.testing.assert_array_equal(np.asarray(tiled),
                                          np.asarray(whole))

    def test_core_use_pallas_dispatch(self):
        """betaincinv(use_pallas=True) broadcasts, ravels through the
        kernel and reshapes back — same tier vs the default path."""
        from repro.core.betainc import betaincinv

        with enable_x64():
            a = np.array([[0.5, 2.0, 8.0]])
            b = np.array([[1.5], [3.0]])
            q = 0.1
            base = np.asarray(betaincinv(a, b, q))
            pallas = np.asarray(betaincinv(a, b, q, use_pallas=True))
            assert pallas.shape == base.shape == (2, 3)
            rel = np.abs(pallas - base) / np.maximum(np.abs(base), 1e-300)
            assert rel.max() < RTOL_BII

    def test_batch_lower_bound_use_pallas(self):
        """The §7.5 fleet entry point: batch_lower_bound(use_pallas=True)
        stays on the <= 1e-10 tier vs the default XLA inversion."""
        from repro.core.batch_decision import batch_lower_bound

        with enable_x64():
            rng = np.random.default_rng(17)
            a = rng.uniform(0.2, 30.0, 128)
            b = rng.uniform(0.2, 30.0, 128)
            base = batch_lower_bound(a, b, 0.1)
            pallas = batch_lower_bound(a, b, 0.1, use_pallas=True)
            rel = np.abs(pallas - base) / np.maximum(np.abs(base), 1e-300)
            assert rel.max() < RTOL_BII

    def test_empty_input(self):
        from repro.kernels.betaincinv_pallas import betaincinv_kernel_call

        out = betaincinv_kernel_call(jnp.zeros(0), jnp.zeros(0),
                                     jnp.zeros(0), interpret=True)
        assert out.shape == (0,)

    def test_drift_monitor_use_pallas_trigger_parity(self):
        """Trigger 2 through the kernel inversion: identical trigger
        events to the default XLA batch path on the same fleet (away
        from razor-edge bounds — the documented interleaving caveat)."""
        from repro.core.drift import DriftMonitor

        with enable_x64():
            rng = np.random.default_rng(23)
            R = 40
            edges = [("u", f"v{i}") for i in range(R)]
            a = rng.uniform(0.5, 40.0, R)
            b = rng.uniform(0.5, 40.0, R)
            al = rng.uniform(0.0, 1.0, R)
            C = rng.uniform(0.001, 0.05, R)
            L = rng.uniform(0.01, 2.0, R)
            events = []
            for use_pallas in (False, True):
                mon = DriftMonitor(credible_consecutive_n=2)
                evs = []
                for _ in range(3):
                    evs.append(mon.check_credible_bound_batch(
                        edges, a, b, al, C, L, use_pallas=use_pallas))
                events.append(evs)
            for e0, e1 in zip(*events):
                assert [x is None for x in e0] == [x is None for x in e1]
                for x0, x1 in zip(e0, e1):
                    if x0 is not None:
                        assert x0.edge == x1.edge
                        assert x0.action == x1.action


def _random_tick_case(seed, N=16, Bp=8, S=8, *, dt=np.float64):
    """A randomized SoA row table + request/settle buckets for the fused
    tick: kill-switch bits cleared on some rows, drift runs seeded near
    the trigger N, duplicate settle rows, -1 padding sentinels."""
    rng = np.random.default_rng(seed)
    post = jnp.asarray(rng.uniform(0.5, 30.0, (N, 2)), dt)
    rowcfg = jnp.asarray(np.stack([
        np.full(N, 0.1),                      # gamma
        rng.uniform(0.9, 1.0, N),             # discount
        rng.uniform(0.0, 0.6, N),             # trigger-2 floor
    ], 1), dt)
    flags = jnp.asarray(np.stack([
        rng.integers(0, 2, N),                # kill-switch bits
        rng.integers(0, 4, N),                # breach runs near N=3
    ], 1).astype(np.int32))
    nreq = rng.integers(1, Bp + 1)
    row = np.full(Bp, -1, np.int32)
    row[:nreq] = rng.integers(0, N, nreq)
    reqs = np.zeros((Bp, 7), dt)
    reqs[:nreq] = np.stack([
        rng.uniform(0.0, 1.0, nreq),          # alpha
        rng.uniform(0.01, 2.0, nreq),         # lambda
        rng.uniform(0.05, 3.0, nreq),         # latency
        rng.integers(10, 2000, nreq),         # in_tok
        rng.integers(10, 2000, nreq),         # out_tok
        np.full(nreq, 3e-6),                  # in_price
        np.full(nreq, 15e-6),                 # out_price
    ], 1)
    nset = rng.integers(0, S + 1)
    out_row = np.full(S, -1, np.int32)
    # duplicates on purpose: same-row settles must compose in order
    out_row[:nset] = rng.integers(0, max(N // 2, 1), nset)
    out_x = np.zeros(S, dt)
    out_x[:nset] = rng.integers(0, 2, nset)
    return post, rowcfg, flags, jnp.asarray(row), jnp.asarray(reqs), \
        jnp.asarray(out_row), jnp.asarray(out_x)


class TestOnlineTickKernel:
    """Fused settle + D4 gate + drift vs `OnlineDecisionService._tick_impl`:
    the mean path is bitwise-f64 (the traced-runtime-zero FMA pin survives
    the Pallas lowering); the lower-bound path sits at the <= 1e-10
    betaincinv tier with decisions still expected to agree away from
    razor-edge thresholds."""

    @staticmethod
    def _reference(post, rowcfg, flags, row, reqs, out_row, out_x, cn,
                   *, use_lower_bound, check_drift):
        import repro.core.online as ol

        state = ol.ServiceState(
            post=post, rowcfg=rowcfg, flags=flags,
            roll=jnp.ones((post.shape[0], 6), jnp.int32),
            tel=jnp.zeros((32, len(ol.TELEMETRY_FIELDS)), post.dtype),
            counters=jnp.zeros(2, jnp.int32))
        # the JITTED tick, exactly as the service dispatches it: calling
        # _tick_impl eagerly would bake `zero` into the settle scan as a
        # constant, XLA would fold the `+ zero` pin away and contract
        # `b*d + (1-x)` into one fma — a 1-ULP-different reference that
        # no real tick ever produces
        return ol._tick(
            state, post.dtype.type(0.0), row, row, reqs,
            jnp.zeros((0, 1), post.dtype), jnp.zeros(0, jnp.int32),
            out_row, out_x, np.int32(cn), jnp.ones(9, jnp.int32),
            use_lower_bound=use_lower_bound, check_drift=check_drift,
            use_rollout=False, use_beam=False)

    @pytest.mark.parametrize("seed", range(6))
    @pytest.mark.parametrize("block_n", [4, 16, 1024])
    def test_mean_path_bitwise(self, seed, block_n):
        from repro.kernels.online_tick import online_tick_kernel_call

        with enable_x64():
            post, rowcfg, flags, row, reqs, out_row, out_x = \
                _random_tick_case(seed)
            cn = 3
            st, rows, bools, trig, _, _ = self._reference(
                post, rowcfg, flags, row, reqs, out_row, out_x, cn,
                use_lower_bound=False, check_drift=True)
            (p2, f2, pu, pm, ev, thr, cs, lv, fl, er, tg) = \
                online_tick_kernel_call(
                    post, rowcfg, flags, jnp.asarray(0.0, post.dtype),
                    row, reqs, out_row, out_x, np.int32(cn),
                    use_lower_bound=False, check_drift=True,
                    block_n=block_n, interpret=True)
            np.testing.assert_array_equal(np.asarray(st.post),
                                          np.asarray(p2), "post")
            np.testing.assert_array_equal(np.asarray(st.flags),
                                          np.asarray(f2), "flags")
            np.testing.assert_array_equal(np.asarray(trig),
                                          np.asarray(tg) > 0, "trig")
            cols = np.asarray(rows)
            np.testing.assert_array_equal(cols[:, 2], np.asarray(pu))
            np.testing.assert_array_equal(cols[:, 3], np.asarray(pm))
            np.testing.assert_array_equal(cols[:, 4], np.asarray(ev))
            np.testing.assert_array_equal(cols[:, 5], np.asarray(thr))
            np.testing.assert_array_equal(cols[:, 7], np.asarray(cs))
            np.testing.assert_array_equal(cols[:, 8], np.asarray(lv))
            b = np.asarray(bools)
            np.testing.assert_array_equal(b[:, 0], np.asarray(fl) > 0)
            np.testing.assert_array_equal(b[:, 1], np.asarray(er) > 0)

    @pytest.mark.parametrize("seed", range(3))
    def test_lower_bound_tier(self, seed):
        from repro.kernels.online_tick import online_tick_kernel_call

        with enable_x64():
            post, rowcfg, flags, row, reqs, out_row, out_x = \
                _random_tick_case(100 + seed)
            st, rows, bools, trig, _, _ = self._reference(
                post, rowcfg, flags, row, reqs, out_row, out_x, 3,
                use_lower_bound=True, check_drift=True)
            (p2, f2, pu, pm, ev, thr, cs, lv, fl, er, tg) = \
                online_tick_kernel_call(
                    post, rowcfg, flags, jnp.asarray(0.0, post.dtype),
                    row, reqs, out_row, out_x, np.int32(3),
                    use_lower_bound=True, check_drift=True,
                    block_n=8, interpret=True)
            # settle is bitwise regardless of the gate's quantile path
            np.testing.assert_array_equal(np.asarray(st.post),
                                          np.asarray(p2))
            cols = np.asarray(rows)
            rel = np.abs(cols[:, 2] - np.asarray(pu)) / np.maximum(
                np.abs(cols[:, 2]), 1e-300)
            assert rel.max() < RTOL_BII
            # P_mean column stays bitwise (no inversion on it)
            np.testing.assert_array_equal(cols[:, 3], np.asarray(pm))
            # decisions agree (thresholds are not razor-edge in this vector)
            np.testing.assert_array_equal(
                np.asarray(bools)[:, 0], np.asarray(fl) > 0)

    def test_drift_breach_run_accumulates_and_triggers(self):
        """Rows seeded one breach short of N: a touching request must
        tick the run to N, trigger, clear the kill-switch bit and reset
        the run — bitwise the `_tick_impl` drift block."""
        from repro.kernels.online_tick import online_tick_kernel_call

        with enable_x64():
            N = 8
            dt = np.float64
            post = jnp.asarray(np.tile([1.0, 9.0], (N, 1)), dt)  # mean 0.1
            rowcfg = jnp.asarray(np.stack([
                np.full(N, 0.1), np.ones(N),
                np.full(N, 0.9),                   # floor far above P_low
            ], 1), dt)
            flags = jnp.asarray(np.stack([
                np.ones(N), np.full(N, 2),         # run = N-1
            ], 1).astype(np.int32))
            row = jnp.asarray(np.array([0, 3, -1, -1], np.int32))
            reqs = jnp.asarray(np.tile(
                np.array([0.5, 1.0, 1.0, 100, 100, 3e-6, 15e-6]), (4, 1)))
            out_row = jnp.asarray(np.full(2, -1, np.int32))
            out_x = jnp.zeros(2, dt)
            st, _, _, trig, _, _ = self._reference(
                post, rowcfg, flags, row, reqs, out_row, out_x, 3,
                use_lower_bound=False, check_drift=True)
            (p2, f2, *_rest, tg) = online_tick_kernel_call(
                post, rowcfg, flags, jnp.asarray(0.0, dt), row, reqs,
                out_row, out_x, np.int32(3), use_lower_bound=False,
                check_drift=True, block_n=4, interpret=True)
            np.testing.assert_array_equal(np.asarray(st.flags),
                                          np.asarray(f2))
            np.testing.assert_array_equal(np.asarray(trig),
                                          np.asarray(tg) > 0)
            tgn = np.asarray(tg) > 0
            assert tgn[0] and tgn[3] and not tgn[1:3].any() \
                and not tgn[4:].any()

    def test_same_row_settles_compose_in_arrival_order(self):
        """Two settles on one row within a tick: the discount recurrence
        must apply them sequentially (a*d+x twice), not gather-last —
        bitwise vs the reference scan."""
        from repro.kernels.online_tick import online_tick_kernel_call

        with enable_x64():
            dt = np.float64
            post = jnp.asarray([[2.0, 3.0], [4.0, 5.0]], dt)
            rowcfg = jnp.asarray([[0.1, 0.9, 0.0], [0.1, 0.95, 0.0]], dt)
            flags = jnp.asarray(np.ones((2, 2), np.int32))
            row = jnp.asarray(np.full(1, -1, np.int32))
            reqs = jnp.zeros((1, 7), dt)
            out_row = jnp.asarray(np.array([0, 0, 1, 0], np.int32))
            out_x = jnp.asarray(np.array([1.0, 0.0, 1.0, 1.0], dt))
            st, *_ = self._reference(
                post, rowcfg, flags, row, reqs, out_row, out_x, 3,
                use_lower_bound=False, check_drift=False)
            p2 = online_tick_kernel_call(
                post, rowcfg, flags, jnp.asarray(0.0, dt), row, reqs,
                out_row, out_x, np.int32(3), use_lower_bound=False,
                check_drift=False, block_n=2, interpret=True)[0]
            np.testing.assert_array_equal(np.asarray(st.post),
                                          np.asarray(p2))


class TestInterpretResolution:
    """kernels.ops._interpret(): the env var is an explicit override;
    unset, backend autodetection decides (native iff TPU) — the
    regression pin for the resolution order, applied uniformly to
    replay_grid and the two new kernel ops (all of which resolve the
    flag OUTSIDE jit and pass it as a static arg)."""

    def test_resolution_order(self, monkeypatch):
        from repro.kernels import ops

        monkeypatch.delenv(ops._INTERPRET_ENV, raising=False)
        assert ops._interpret() == (not ops.on_tpu())
        for v in ("1", "true", "YES", " interpret "):
            monkeypatch.setenv(ops._INTERPRET_ENV, v)
            assert ops._interpret() is True, v
        for v in ("0", "false", "native", "no"):
            monkeypatch.setenv(ops._INTERPRET_ENV, v)
            assert ops._interpret() is False, v
        # empty string == unset: autodetection, not forced-native
        monkeypatch.setenv(ops._INTERPRET_ENV, "")
        assert ops._interpret() == (not ops.on_tpu())

    def test_flag_not_baked_into_trace(self, monkeypatch):
        """Flipping the env var between calls must be honored: the jitted
        ops take `interpret` as a static arg resolved per call, so the
        override cannot be frozen into the first executable."""
        from repro.kernels import ops

        with enable_x64():
            a = jnp.asarray(np.array([2.0, 0.5]))
            b = jnp.asarray(np.array([3.0, 0.5]))
            q = jnp.asarray(np.array([0.1, 0.5]))
            monkeypatch.setenv(ops._INTERPRET_ENV, "interpret")
            first = np.asarray(ops.betaincinv_op(a, b, q))
            # still-interpret after a flip back and forth; on CPU the
            # native branch cannot lower, so resolution landing on
            # interpret both times IS the observable contract
            monkeypatch.setenv(ops._INTERPRET_ENV, "1")
            second = np.asarray(ops.betaincinv_op(a, b, q))
            np.testing.assert_array_equal(first, second)
