"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True on CPU; same kernels compile natively on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # interpret=True Pallas sweeps

from repro.kernels import (
    decode_attention_op,
    flash_attention,
    rglru_scan_op,
    ssd_scan_op,
)
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.ref import (
    reference_attention,
    reference_decode_attention,
    reference_rglru_scan,
    reference_ssd_scan,
)

TOL = dict(atol=2e-2, rtol=2e-2)      # bf16 sweeps
TOL32 = dict(atol=2e-5, rtol=2e-5)    # f32 sweeps


def tols(dtype):
    return TOL if dtype == jnp.bfloat16 else TOL32


class TestFlashAttention:
    @pytest.mark.parametrize("S,H,Hkv,D", [
        (128, 4, 4, 64),     # MHA
        (256, 8, 2, 64),     # GQA 4:1
        (192, 8, 1, 32),     # MQA, ragged seq (pads)
        (256, 4, 4, 128),    # wider head
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal_sweep(self, S, H, Hkv, D, dtype):
        q = jax.random.normal(jax.random.key(1), (2, S, H, D), dtype)
        k = jax.random.normal(jax.random.key(2), (2, S, Hkv, D), dtype)
        v = jax.random.normal(jax.random.key(3), (2, S, Hkv, D), dtype)
        out = flash_attention_fwd(q, k, v, block_q=64, block_k=64, interpret=True)
        ref = reference_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), **tols(dtype))

    @pytest.mark.parametrize("window", [16, 64])
    def test_sliding_window(self, window):
        q = jax.random.normal(jax.random.key(1), (1, 256, 4, 32))
        k = jax.random.normal(jax.random.key(2), (1, 256, 1, 32))
        v = jax.random.normal(jax.random.key(3), (1, 256, 1, 32))
        out = flash_attention_fwd(q, k, v, window=window, block_q=64,
                                  block_k=64, interpret=True)
        ref = reference_attention(q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL32)

    def test_custom_vjp_matches_reference_grad(self):
        q = jax.random.normal(jax.random.key(1), (1, 64, 2, 32))
        k = jax.random.normal(jax.random.key(2), (1, 64, 2, 32))
        v = jax.random.normal(jax.random.key(3), (1, 64, 2, 32))
        g1 = jax.grad(lambda q: flash_attention(q, k, v).sum())(q)
        g2 = jax.grad(lambda q: reference_attention(q, k, v).sum())(q)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), **TOL32)


class TestDecodeAttention:
    @pytest.mark.parametrize("C,H,Hkv,D", [
        (96, 8, 2, 64), (128, 4, 1, 32), (100, 4, 4, 64),
    ])
    def test_partial_cache_and_masks(self, C, H, Hkv, D):
        B = 2
        q = jax.random.normal(jax.random.key(1), (B, H, D))
        kc = jax.random.normal(jax.random.key(2), (B, C, Hkv, D))
        vc = jax.random.normal(jax.random.key(3), (B, C, Hkv, D))
        pos = jnp.broadcast_to(jnp.arange(C)[None], (B, C)).astype(jnp.int32)
        pos = pos.at[:, int(0.8 * C):].set(-1)
        cur = jnp.array([int(0.5 * C), int(0.7 * C)], jnp.int32)
        out = decode_attention_op(q, kc, vc, pos, cur)
        ref = reference_decode_attention(q, kc, vc, pos, cur)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL32)

    def test_window_masking(self):
        B, C, H, D = 1, 64, 2, 32
        q = jax.random.normal(jax.random.key(1), (B, H, D))
        kc = jax.random.normal(jax.random.key(2), (B, C, 1, D))
        vc = jax.random.normal(jax.random.key(3), (B, C, 1, D))
        pos = jnp.arange(C)[None].astype(jnp.int32)
        cur = jnp.array([60], jnp.int32)
        from repro.kernels.decode_attention import decode_attention_kernel_call
        out = decode_attention_kernel_call(q, kc, vc, pos, cur, window=16,
                                           interpret=True)
        ref = reference_decode_attention(q, kc, vc, pos, cur, window=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL32)


class TestRglruScan:
    @pytest.mark.parametrize("B,T,C", [(2, 200, 96), (1, 64, 128), (3, 130, 64)])
    def test_sweep(self, B, T, C):
        a = jax.nn.sigmoid(jax.random.normal(jax.random.key(4), (B, T, C)))
        b = jax.random.normal(jax.random.key(5), (B, T, C))
        h0 = jax.random.normal(jax.random.key(6), (B, C))
        out = rglru_scan_op(a, b, h0)
        ref = reference_rglru_scan(a, b, h0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)

    def test_zero_state_start(self):
        a = jnp.full((1, 32, 16), 0.5)
        b = jnp.ones((1, 32, 16))
        out = rglru_scan_op(a, b, None)
        ref = reference_rglru_scan(a, b, None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


class TestSsdScan:
    @pytest.mark.parametrize("S,H,P,N,chunk", [
        (96, 4, 16, 32, 32), (128, 2, 32, 16, 64), (100, 4, 16, 32, 32),
    ])
    def test_sweep(self, S, H, P, N, chunk):
        B = 2
        x = jax.random.normal(jax.random.key(7), (B, S, H, P)) * 0.5
        A = -jnp.abs(jax.random.normal(jax.random.key(8), (B, S, H))) * 0.1
        Bm = jax.random.normal(jax.random.key(9), (B, S, N)) * 0.5
        Cm = jax.random.normal(jax.random.key(10), (B, S, N)) * 0.5
        y = ssd_scan_op(x, A, Bm, Cm, chunk=chunk)
        yref, _ = reference_ssd_scan(x, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   atol=1e-4, rtol=1e-4)

    def test_matches_model_ssd_chunked(self):
        """Kernel == the model's chunked SSD (same math, different tiling)."""
        from repro.models.ssd import ssd_chunked
        B, S, H, P, N = 1, 64, 2, 16, 32
        x = jax.random.normal(jax.random.key(7), (B, S, H, P)) * 0.5
        A = -jnp.abs(jax.random.normal(jax.random.key(8), (B, S, H))) * 0.1
        Bm = jax.random.normal(jax.random.key(9), (B, S, N)) * 0.5
        Cm = jax.random.normal(jax.random.key(10), (B, S, N)) * 0.5
        y_kernel = ssd_scan_op(x, A, Bm, Cm, chunk=32)
        y_model, _ = ssd_chunked(x, A, Bm[:, :, None, :], Cm[:, :, None, :], 32)
        np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                                   atol=1e-4, rtol=1e-4)
