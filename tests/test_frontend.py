"""Serving front-end unit tests: circuit breaker, bulkhead, batching,
fallback chain, ring-encoded resilience events — plus the spec_bridge
regressions (worker exceptions, upstream-failure cleanup, timeouts,
retry/backoff) and the online service's idle-tick fast path."""
import threading
import time

import numpy as np
import pytest

from repro.core.decision import Decision
from repro.core.online import OnlineDecisionService, TELEMETRY_FIELDS
from repro.core.posterior import BetaPosterior
from repro.core.telemetry import (
    RESILIENCE_KINDS,
    ResilienceEvent,
    ResilienceLog,
)
from repro.serving.engine import GenerationResult
from repro.serving.frontend import (
    BreakerState,
    CircuitBreaker,
    DecisionRequest,
    FrontendConfig,
    ServingFrontend,
    TenantBulkhead,
)
from repro.serving.spec_bridge import (
    SpeculationTimeout,
    ThreadedSpeculativeRunner,
    call_with_timeout,
    retry_with_backoff,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _service(n_edges=2, tenant="t0", **kw):
    svc = OnlineDecisionService(**kw)
    for e in range(n_edges):
        svc.register_edge(
            (f"u{e}", f"v{e}"), tenant=tenant,
            posterior=BetaPosterior(alpha=16.0, beta=2.0))
    return svc


def _req(row=0, tenant="t0", edge=("u0", "v0"), **kw):
    base = dict(alpha=0.5, lambda_usd_per_s=0.9, latency_s=3.0,
                input_tokens=500.0, output_tokens=300.0,
                input_price=3e-6, output_price=15e-6)
    base.update(kw)
    return DecisionRequest(row=row, tenant=tenant, edge=edge, **base)


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------
class TestCircuitBreaker:
    def test_opens_after_consecutive_failures(self):
        clk = FakeClock()
        br = CircuitBreaker(failure_threshold=3, cooldown_s=1.0, clock=clk)
        for _ in range(2):
            br.record_failure("k")
        assert br.state("k") is BreakerState.CLOSED and br.allow("k")
        br.record_failure("k")
        assert br.state("k") is BreakerState.OPEN
        assert not br.allow("k")

    def test_success_resets_failure_run(self):
        br = CircuitBreaker(failure_threshold=2, clock=FakeClock())
        br.record_failure("k")
        br.record_success("k")
        br.record_failure("k")
        assert br.state("k") is BreakerState.CLOSED

    def test_half_open_probe_budget_and_close(self):
        clk = FakeClock()
        br = CircuitBreaker(failure_threshold=1, cooldown_s=1.0,
                            half_open_probes=1, clock=clk)
        br.record_failure("k")
        assert not br.allow("k")              # open, inside cooldown
        clk.t = 1.5
        assert br.allow("k")                  # cooldown elapsed -> probe
        assert br.state("k") is BreakerState.HALF_OPEN
        assert not br.allow("k")              # probe budget exhausted
        br.record_success("k")
        assert br.state("k") is BreakerState.CLOSED
        assert br.allow("k")

    def test_half_open_probe_failure_reopens(self):
        clk = FakeClock()
        br = CircuitBreaker(failure_threshold=1, cooldown_s=1.0, clock=clk)
        br.record_failure("k")
        clk.t = 1.5
        assert br.allow("k")
        br.record_failure("k")
        assert br.state("k") is BreakerState.OPEN
        clk.t = 2.0                           # cooldown restarted at 1.5
        assert not br.allow("k")
        clk.t = 2.6
        assert br.allow("k")

    def test_trip_opens_immediately_and_keys_isolated(self):
        clk = FakeClock()
        br = CircuitBreaker(failure_threshold=5, cooldown_s=1.0, clock=clk)
        br.trip("a")
        assert br.state("a") is BreakerState.OPEN and br.trips == 1
        assert br.allow("b")                  # other keys unaffected

    def test_transition_callback_sequence(self):
        clk = FakeClock()
        seen = []
        br = CircuitBreaker(failure_threshold=1, cooldown_s=1.0, clock=clk,
                            on_transition=lambda k, s: seen.append(s))
        br.record_failure("k")
        clk.t = 1.5
        br.allow("k")
        br.record_success("k")
        assert seen == [BreakerState.OPEN, BreakerState.HALF_OPEN,
                        BreakerState.CLOSED]

    def test_validation(self):
        with pytest.raises(ValueError):
            CircuitBreaker(failure_threshold=0)
        with pytest.raises(ValueError):
            CircuitBreaker(half_open_probes=0)


class TestTenantBulkhead:
    def test_limit_and_release(self):
        bh = TenantBulkhead(2)
        assert bh.try_acquire("a") and bh.try_acquire("a")
        assert not bh.try_acquire("a")        # at limit
        assert bh.try_acquire("b")            # independent tenant
        bh.release("a")
        assert bh.try_acquire("a")
        assert bh.in_flight("a") == 2

    def test_release_without_acquire_raises(self):
        bh = TenantBulkhead(1)
        with pytest.raises(RuntimeError):
            bh.release("a")

    def test_limit_validated(self):
        with pytest.raises(ValueError):
            TenantBulkhead(0)


# ---------------------------------------------------------------------------
# the frontend chain
# ---------------------------------------------------------------------------
class TestFrontendChain:
    def test_pump_batches_and_answers_from_service(self):
        fe = ServingFrontend(_service(), FrontendConfig(max_batch=4),
                             autostart=False)
        tks = [fe.submit(_req()) for _ in range(3)]
        assert all(not t.done() for t in tks)     # accumulating
        assert fe.pump() == 3
        for t in tks:
            res = t.result(0)
            assert res.source == "service"
            if res.speculate:
                t.settle(True)
        assert fe.stats["deadline_ticks"] == 1    # partial batch

    def test_batch_full_pump_consumes_max_batch(self):
        fe = ServingFrontend(_service(), FrontendConfig(max_batch=2),
                             autostart=False)
        tks = [fe.submit(_req()) for _ in range(3)]
        assert fe.pump() == 2 and fe.stats["full_ticks"] == 1
        assert tks[0].done() and not tks[2].done()
        fe.pump()
        for t in tks:
            if t.result(0).speculate:
                t.settle(True)

    def test_bulkhead_shed_answers_conservative_with_usd_event(self):
        fe = ServingFrontend(_service(), FrontendConfig(bulkhead_limit=1),
                             autostart=False)
        t1, t2 = fe.submit(_req()), fe.submit(_req())
        res = t2.result(0)                        # shed synchronously
        assert res.source == "shed" and res.decision is Decision.WAIT
        ev = fe.resilience.events[-1]
        assert ev.kind == "shed" and ev.tenant == "t0"
        assert ev.usd == pytest.approx(3.0 * 0.9)  # L * lambda at stake
        fe.pump()
        if t1.result(0).speculate:
            t1.settle(True)

    def test_queue_limit_sheds(self):
        fe = ServingFrontend(
            _service(), FrontendConfig(max_queue=2, max_batch=64,
                                       bulkhead_limit=64),
            autostart=False)
        tks = [fe.submit(_req()) for _ in range(4)]
        sources = [t.result(0).source if t.done() else None for t in tks]
        assert sources[2:] == ["shed", "shed"]
        assert fe.stats["shed"] == 2

    def test_breaker_open_degrades_to_scalar_bitwise(self):
        from jax.experimental import enable_x64

        from repro.core.decision import DecisionInputs, evaluate

        with enable_x64():
            svc = _service()
            fe = ServingFrontend(svc, FrontendConfig(), autostart=False)
            snap = svc.posterior_snapshot()
            r = _req()
            fe.breaker.trip(r.key)
            tk = fe.submit(r)
            res = tk.result(0)                    # answered synchronously
            assert res.source == "scalar"
            post = BetaPosterior(alpha=float(snap[0, 0]),
                                 beta=float(snap[0, 1]))
            ref = evaluate(DecisionInputs(
                P=post.mean, alpha=r.alpha,
                lambda_usd_per_s=r.lambda_usd_per_s,
                latency_seconds=r.latency_s, input_tokens=r.input_tokens,
                output_tokens=r.output_tokens, input_price=r.input_price,
                output_price=r.output_price))
            assert res.decision is ref.decision
            assert res.EV_usd == ref.EV_usd
            assert res.threshold_usd == ref.threshold_usd
            assert res.P_used == ref.P_used
            if res.speculate:
                tk.release()
        kinds = fe.resilience.by_kind()
        assert kinds.get("fallback_scalar") == 1

    def test_terminal_conservative_stage(self):
        # an out-of-range alpha makes the scalar stage raise, so the
        # chain's terminal stage answers WAIT — the sequential path is
        # never blocked by a bad request on a degraded edge
        fe = ServingFrontend(_service(), FrontendConfig(), autostart=False)
        bad = _req(alpha=1.5)
        fe.breaker.trip(bad.key)
        res = fe.submit(bad).result(0)
        assert res.source == "conservative"
        assert res.decision is Decision.WAIT
        assert fe.resilience.by_kind().get("fallback_conservative") == 1

    def test_tick_exception_degrades_whole_batch_and_feeds_breaker(self):
        class Exploding:
            def __init__(self, svc):
                self._svc = svc

            def __getattr__(self, name):
                if name == "tick_packed":
                    raise_ = lambda *a, **k: (_ for _ in ()).throw(  # noqa: E731
                        RuntimeError("boom"))
                    return raise_
                return getattr(self._svc, name)

        fe = ServingFrontend(
            Exploding(_service()),
            FrontendConfig(max_batch=4, breaker_failure_threshold=1),
            autostart=False)
        tks = [fe.submit(_req()) for _ in range(2)]
        fe.pump()
        for t in tks:
            res = t.result(0)
            assert res.source == "scalar"
            if res.speculate:
                t.release()
        assert fe.stats["tick_faults"] == 1
        assert fe.breaker.state(("t0", ("u0", "v0"))) is BreakerState.OPEN
        kinds = fe.resilience.by_kind()
        assert kinds["exception"] == 2 and kinds["breaker_open"] == 1

    def test_settle_feeds_service_posterior(self):
        svc = _service()
        fe = ServingFrontend(svc, FrontendConfig(), autostart=False)
        before = svc.posterior_snapshot()[0].copy()
        tk = fe.submit(_req())
        fe.pump()
        assert tk.result(0).speculate
        tk.settle(False)
        assert fe.in_flight("t0") == 0            # slot released
        fe.submit(_req())
        fe.pump()                                 # settle applies pre-tick
        after = svc.posterior_snapshot()[0]
        assert after[1] == pytest.approx(before[1] + 1.0)  # one failure

    def test_settle_twice_raises(self):
        fe = ServingFrontend(_service(), FrontendConfig(), autostart=False)
        tk = fe.submit(_req())
        fe.pump()
        if tk.result(0).speculate:
            tk.settle(True)
            with pytest.raises(RuntimeError):
                tk.settle(True)

    def test_events_mirrored_to_device_ring(self):
        svc = _service()
        fe = ServingFrontend(svc, FrontendConfig(bulkhead_limit=1),
                             autostart=False)
        fe.submit(_req())
        fe.submit(_req())                         # shed -> ring event
        fe.pump()
        tb = svc.drain_telemetry()
        assert any(e["kind"] == "shed" and e["row"] == 0 for e in tb.events)
        # decision rows in the same window keep the full field schema
        assert set(tb.fields) == set(TELEMETRY_FIELDS)


# ---------------------------------------------------------------------------
# resilience event log + ring encoding
# ---------------------------------------------------------------------------
class TestDriftTripDedup:
    """A kill-switch breach trips the breaker once per breach *onset*:
    repeated triggered pulses while the row is down are swallowed, but a
    second breach after an observed recovery re-emits a fresh trip."""

    def _stack(self):
        from repro.core.rollout import RolloutConfig, RolloutController
        svc = OnlineDecisionService(credible_consecutive_n=2)
        svc.register_edge(
            ("u0", "v0"), tenant="t0",
            posterior=BetaPosterior(alpha=16.0, beta=2.0), discount=0.85,
            floor_alpha=0.3, floor_C_spec_usd=1.0, floor_L_value_usd=1.0)
        ctl = RolloutController(
            svc, RolloutConfig(cooldown_ticks=3, probe_budget=8,
                               canary_period=1, min_obs=(2, 2, 2),
                               promote_rate=(0.5, 0.5, 0.5)))
        clk = FakeClock()
        fe = ServingFrontend(
            ctl, FrontendConfig(max_batch=2, check_drift=True,
                                breaker_cooldown_s=0.2),
            clock=clk, autostart=False)
        return svc, ctl, fe, clk

    @staticmethod
    def _tick(fe, clk, ok):
        clk.t += 0.05
        tk = fe.submit(_req())
        fe.pump()
        tk.result(0)
        tk.settle(ok)

    def test_second_breach_after_recovery_reemits_trip(self):
        svc, ctl, fe, clk = self._stack()

        def trips():
            return sum(e.kind == "drift_trip" for e in fe.resilience.events)

        for _ in range(12):                       # climb to FULL
            self._tick(fe, clk, True)
        assert ctl.phases() == ["FULL"] and trips() == 0
        i = 0
        while trips() == 0 and i < 60:            # breach #1
            self._tick(fe, clk, False)
            i += 1
        assert trips() == 1
        for _ in range(6):                        # still down: no re-trip
            self._tick(fe, clk, False)
        assert trips() == 1
        j = 0
        while ctl.phases() != ["FULL"] and j < 200:   # recover
            self._tick(fe, clk, True)
            j += 1
        assert ctl.phases() == ["FULL"] and trips() == 1
        i = 0
        while trips() == 1 and i < 60:            # breach #2 re-emits
            self._tick(fe, clk, False)
            i += 1
        assert trips() == 2


class TestResilienceTelemetry:
    def test_event_kind_validated(self):
        with pytest.raises(ValueError):
            ResilienceEvent(kind="nonsense")

    def test_usd_attribution_sums_per_tenant_kind(self):
        log = ResilienceLog()
        log.emit(ResilienceEvent(kind="shed", tenant="a", usd=1.5))
        log.emit(ResilienceEvent(kind="shed", tenant="a", usd=0.5))
        log.emit(ResilienceEvent(kind="timeout", tenant="b", usd=2.0))
        att = log.usd_attribution()
        assert att[("a", "shed")] == pytest.approx(2.0)
        assert att[("b", "timeout")] == pytest.approx(2.0)
        assert log.by_kind() == {"shed": 2, "timeout": 1}

    def test_log_events_roundtrip_all_kinds(self):
        svc = _service()
        svc.log_events([(None, k, 0.25 * i)
                        for i, k in enumerate(RESILIENCE_KINDS)])
        svc.log_events([(1, "shed", 9.0)])
        tb = svc.drain_telemetry()
        assert [e["kind"] for e in tb.events[:-1]] == list(RESILIENCE_KINDS)
        assert tb.events[0]["row"] is None
        assert tb.events[-1] == {"kind": "shed", "row": 1, "usd": 9.0}
        assert tb.events_dropped == 0
        assert len(tb) == 0                       # no decision rows emitted

    def test_log_events_bad_row_raises(self):
        svc = _service()
        with pytest.raises(IndexError):
            svc.log_events([(99, "shed", 0.0)])

    def test_event_overflow_counted_dropped(self):
        svc = _service(telemetry_capacity=4)
        # a 6-event burst buckets to 8 slots; the 4-slot ring keeps the
        # newest slots (2 real events + the bucket's padding) and the
        # drain accounts for every evicted real event
        svc.log_events([(None, "shed", float(i)) for i in range(6)])
        tb = svc.drain_telemetry()
        assert len(tb.events) == 2
        assert tb.events_dropped == 4
        assert [e["usd"] for e in tb.events] == [4.0, 5.0]

    def test_decision_rows_and_events_share_window(self):
        svc = _service()
        svc.tick([0], alpha=0.5, lambda_usd_per_s=0.9, latency_s=3.0,
                 input_tokens=500, output_tokens=300, input_price=3e-6,
                 output_price=15e-6)
        svc.log_events([(0, "breaker_open", 0.01)])
        tb = svc.drain_telemetry()
        assert len(tb) == 1 and tb.dropped == 0   # the decision row
        assert [e["kind"] for e in tb.events] == ["breaker_open"]


# ---------------------------------------------------------------------------
# idle-tick fast path (PR 5 perf note)
# ---------------------------------------------------------------------------
class TestIdleTickFastPath:
    def test_idle_tick_skips_dispatch_and_preserves_state(self):
        svc = _service()
        svc.tick([0], alpha=0.5, lambda_usd_per_s=0.9, latency_s=3.0,
                 input_tokens=500, output_tokens=300, input_price=3e-6,
                 output_price=15e-6)
        snap = svc.posterior_snapshot()
        drained = svc.drain_telemetry()
        assert len(drained) == 1
        d = svc.tick_packed(np.zeros(0, np.int32),
                            np.zeros((0, 7), np.float64))
        assert svc.idle_ticks_skipped == 1
        assert d.speculate.shape == (0,)
        assert not d.drift_triggered.any()
        # bitwise: nothing moved, nothing new to drain
        assert np.array_equal(svc.posterior_snapshot(), snap)
        tb = svc.drain_telemetry()
        assert len(tb) == 0 and tb.dropped == 0 and tb.events == []

    def test_idle_sequence_parity_with_dispatching_service(self):
        # a service that sleeps through idle ticks must answer the next
        # real tick bitwise identically to one that never idled
        def run(idle_ticks):
            svc = _service()
            for _ in range(idle_ticks):
                svc.tick_packed(np.zeros(0, np.int32),
                                np.zeros((0, 7), svc.state.post.dtype))
            d = svc.tick([0, 1], alpha=0.5, lambda_usd_per_s=0.9,
                         latency_s=3.0, input_tokens=500, output_tokens=300,
                         input_price=3e-6, output_price=15e-6,
                         outcomes=[(0, True)], check_drift=True)
            return (np.asarray(d.EV_usd).copy(),
                    np.asarray(d.speculate).copy(),
                    svc.posterior_snapshot())

        ev0, sp0, post0 = run(0)
        ev5, sp5, post5 = run(5)
        assert np.array_equal(ev0, ev5)
        assert np.array_equal(sp0, sp5)
        assert np.array_equal(post0, post5)

    def test_pending_outcomes_defeat_fast_path(self):
        svc = _service()
        svc.observe(0, False)
        svc.tick_packed(np.zeros(0, np.int32), np.zeros((0, 7), np.float64))
        assert svc.idle_ticks_skipped == 0        # outcome had to settle
        assert svc.posterior_snapshot()[0, 1] == pytest.approx(3.0)


# ---------------------------------------------------------------------------
# spec_bridge regressions
# ---------------------------------------------------------------------------
class _StubDownstream:
    """EngineOp-shaped double: scripted (exception | timeout | result)
    per call, cancel-aware."""

    name = "stub"
    provider = "paper"
    model = "frontier-default"
    max_new_tokens = 8

    def __init__(self, script):
        self.script = list(script)
        self.calls = 0
        self.saw_cancel = threading.Event()

    def run(self, upstream_output, cancel_event=None):
        self.calls += 1
        step = self.script.pop(0)
        if step == "hang_until_cancelled":
            assert cancel_event is not None
            assert cancel_event.wait(5.0), "speculative thread never cancelled"
            self.saw_cancel.set()
            return [1], GenerationResult(
                tokens=[1], cancelled=True, prompt_len=1,
                wall_time_s=0.01, tokens_generated=1)
        if isinstance(step, BaseException):
            raise step
        return step, GenerationResult(
            tokens=list(step), cancelled=False, prompt_len=1,
            wall_time_s=0.01, tokens_generated=len(step))


class TestSpecBridgeRegressions:
    def test_worker_exception_propagates_not_keyerror(self):
        # pre-fix: the thread died silently and join-time access raised
        # KeyError("out"); the defect must surface as the real exception
        runner = ThreadedSpeculativeRunner(
            lambda: ("match", None), _StubDownstream([RuntimeError("gpu")]))
        with pytest.raises(RuntimeError, match="gpu"):
            runner.run_speculative("match")

    def test_worker_exception_propagates_on_tier_failure_too(self):
        runner = ThreadedSpeculativeRunner(
            lambda: ("actual", None), _StubDownstream([RuntimeError("gpu")]))
        with pytest.raises(RuntimeError, match="gpu"):
            runner.run_speculative("a long and completely different i_hat")

    def test_upstream_failure_cancels_and_joins_speculation(self):
        # pre-fix: the upstream exception propagated while the worker
        # thread kept generating forever with nobody left to cancel it
        stub = _StubDownstream(["hang_until_cancelled"])

        def upstream():
            time.sleep(0.02)                  # let the worker start
            raise ConnectionError("upstream died")

        runner = ThreadedSpeculativeRunner(upstream, stub)
        with pytest.raises(ConnectionError):
            runner.run_speculative("anything")
        assert stub.saw_cancel.is_set()       # cancelled AND joined

    def test_timeout_settles_as_failed_speculation(self):
        svc = _service(n_edges=1)
        stub = _StubDownstream([SpeculationTimeout("deadline"), [7, 8]])
        runner = ThreadedSpeculativeRunner(
            lambda: ("match", None), stub,
            service=svc, edge=("u0", "v0"), tenant="t0")
        res = runner.run_speculative("match")
        assert res.timed_out and not res.committed and res.cancelled
        assert res.waste_usd > 0.0            # full planned output billed
        assert res.downstream_output == [7, 8]  # sequential re-execution
        assert stub.calls == 2
        # the failure observation reached the service's settle queue
        assert svc._pending == [(0, False)]

    def test_timeout_on_tier_failure_bills_plan(self):
        stub = _StubDownstream([SpeculationTimeout("deadline"), [9]])
        runner = ThreadedSpeculativeRunner(
            lambda: ("actual", None), stub)
        res = runner.run_speculative("a long and completely different i_hat")
        assert res.timed_out and res.cancelled and not res.committed
        assert res.waste_usd > 0.0

    def test_call_with_timeout(self):
        assert call_with_timeout(lambda: 42, 1.0) == 42
        with pytest.raises(SpeculationTimeout):
            call_with_timeout(lambda: time.sleep(0.5), 0.02)
        with pytest.raises(ZeroDivisionError):
            call_with_timeout(lambda: 1 / 0, 1.0)

    def test_retry_with_backoff_counts_and_sleeps(self):
        calls, sleeps = [], []
        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise OSError("transient")
            return "ok"
        assert retry_with_backoff(flaky, retries=3, backoff_s=0.1,
                                  sleep=sleeps.append) == "ok"
        assert len(calls) == 3
        assert sleeps == [pytest.approx(0.1), pytest.approx(0.2)]

    def test_retry_exhaustion_propagates_final_error(self):
        sleeps = []
        def always():
            raise OSError("down")
        with pytest.raises(OSError):
            retry_with_backoff(always, retries=2, backoff_s=0.01,
                               sleep=sleeps.append)
        assert len(sleeps) == 2               # no sleep after last attempt
        with pytest.raises(ValueError):
            retry_with_backoff(always, retries=-1)
