"""D3/D4 decision-rule tests: paper numbers + hypothesis invariants."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.decision import (
    Decision,
    DecisionInputs,
    LambdaDerivation,
    critical_k,
    decision_threshold,
    evaluate,
    expected_value,
    implied_lambda,
    p_break_even,
    p_threshold_crossing,
    speculation_decision,
)

# canonical parameter sets (DESIGN.md)
WORKED = dict(input_tokens=500, output_tokens=1000, input_price=3e-6,
              output_price=15e-6, latency_seconds=5.0,
              lambda_dollars_per_sec=0.01)          # §10.1: C=0.0165, L=0.05
AUTOREPLY_C = 500 * 3e-6 + 800 * 15e-6              # 0.0135
AUTOREPLY_L = 0.8 * 0.08                            # 0.064


class TestPaperNumbers:
    def test_worked_example_costs(self):
        res = evaluate(DecisionInputs(
            P=0.733, alpha=0.5, lambda_usd_per_s=0.01, latency_seconds=5.0,
            input_tokens=500, output_tokens=1000,
            input_price=3e-6, output_price=15e-6,
        ))
        assert res.C_spec_usd == pytest.approx(0.0165)
        assert res.L_value_usd == pytest.approx(0.05)
        # §10.1: EV = 0.733*0.05 - 0.267*0.0165
        assert res.EV_usd == pytest.approx(0.733 * 0.05 - 0.267 * 0.0165, abs=1e-9)
        assert res.decision == Decision.SPECULATE

    @pytest.mark.parametrize("alpha,expected", [
        (0.0, "SPECULATE"), (0.2, "SPECULATE"), (0.5, "SPECULATE"),
        (0.8, "SPECULATE"), (1.0, "SPECULATE"),
    ])
    def test_alpha_sensitivity_high_p(self, alpha, expected):
        assert speculation_decision(0.733, alpha, 0.01, 500, 1000,
                                    3e-6, 15e-6, 5.0) == expected

    @pytest.mark.parametrize("alpha,expected", [
        (0.0, "WAIT"), (0.2, "WAIT"), (0.5, "SPECULATE"),
        (0.8, "SPECULATE"), (1.0, "SPECULATE"),
    ])
    def test_alpha_sensitivity_low_p(self, alpha, expected):
        """§10.1 P = 0.4 table: flips at alpha ~ 0.4."""
        assert speculation_decision(0.4, alpha, 0.01, 500, 1000,
                                    3e-6, 15e-6, 5.0) == expected

    def test_critical_k_autoreply(self):
        """§7.6: k_crit(0)~2.87, k_crit(0.5)~3.83, k_crit(1)~5.74."""
        assert critical_k(AUTOREPLY_L, AUTOREPLY_C, 0.0) == pytest.approx(2.87, abs=0.01)
        assert critical_k(AUTOREPLY_L, AUTOREPLY_C, 0.5) == pytest.approx(3.83, abs=0.01)
        assert critical_k(AUTOREPLY_L, AUTOREPLY_C, 1.0) == pytest.approx(5.74, abs=0.01)

    @pytest.mark.parametrize("k,ev,decisions", [
        (2, 0.0253, ("SPECULATE", "SPECULATE", "SPECULATE")),
        (3, 0.0123, ("WAIT", "SPECULATE", "SPECULATE")),
        (5, 0.0020, ("WAIT", "WAIT", "SPECULATE")),
        (10, -0.0058, ("WAIT", "WAIT", "WAIT")),
        (20, -0.0096, ("WAIT", "WAIT", "WAIT")),
    ])
    def test_branching_table(self, k, ev, decisions):
        """§7.6 numerical table at AutoReply parameters."""
        P = 1.0 / k
        assert expected_value(P, AUTOREPLY_L, AUTOREPLY_C) == pytest.approx(ev, abs=5e-4)
        for alpha, want in zip((0.0, 0.5, 1.0), decisions):
            got = ("SPECULATE" if expected_value(P, AUTOREPLY_L, AUTOREPLY_C)
                   >= decision_threshold(alpha, AUTOREPLY_C) else "WAIT")
            assert got == want, f"k={k} alpha={alpha}"

    def test_skewed_classifier_example(self):
        """§7.6: 62% 'billing' -> EV = +$0.0346, SPECULATE at all alpha."""
        ev = expected_value(0.62, AUTOREPLY_L, AUTOREPLY_C)
        assert ev == pytest.approx(0.0346, abs=5e-4)
        for alpha in (0.0, 0.5, 1.0):
            assert ev >= decision_threshold(alpha, AUTOREPLY_C)

    def test_implied_lambda_d5(self):
        """App. D.5: lambda_implied(0.5) ~ 0.024, (0.9) ~ 0.013."""
        assert implied_lambda(0.62, AUTOREPLY_C, 0.5, 0.8) == pytest.approx(0.024, abs=1e-3)
        assert implied_lambda(0.62, AUTOREPLY_C, 0.9, 0.8) == pytest.approx(0.013, abs=1e-3)

    def test_two_phase_posterior_drop(self):
        """§10.2: P 0.733 -> 0.55 narrows the margin but still SPECULATE."""
        res = evaluate(DecisionInputs(
            P=0.55, alpha=0.5, lambda_usd_per_s=0.01, latency_seconds=5.0,
            input_tokens=500, output_tokens=1000,
            input_price=3e-6, output_price=15e-6,
        ))
        assert res.EV_usd == pytest.approx(0.0201, abs=1e-4)
        assert res.decision == Decision.SPECULATE
        # Paper §10.2 claims alpha=0.1 -> WAIT, but EV $0.0201 > threshold
        # $0.01485 under the paper's own D4 rule -> SPECULATE (paper
        # inconsistency #3, DESIGN.md).  A true downgrade needs lower P:
        res2 = evaluate(DecisionInputs(
            P=0.55, alpha=0.1, lambda_usd_per_s=0.01, latency_seconds=5.0,
            input_tokens=500, output_tokens=1000,
            input_price=3e-6, output_price=15e-6,
        ))
        assert res2.threshold_usd == pytest.approx(0.01485)
        assert res2.decision == Decision.SPECULATE  # rule arithmetic wins
        res3 = evaluate(DecisionInputs(
            P=0.35, alpha=0.1, lambda_usd_per_s=0.01, latency_seconds=5.0,
            input_tokens=500, output_tokens=1000,
            input_price=3e-6, output_price=15e-6,
        ))
        assert res3.decision == Decision.WAIT  # bidirectional downgrade

    def test_lambda_derivations(self):
        """§5.3 table."""
        assert LambdaDerivation.user_value_of_time(1.0, 60.0) == pytest.approx(0.0167, abs=1e-4)
        assert LambdaDerivation.labor_cost(100.0) == pytest.approx(0.0278, abs=1e-4)
        assert LambdaDerivation.workflow_value(10.0, 100.0) == pytest.approx(0.10)
        assert LambdaDerivation.budget_deadline(10.0, 5.0, 100.0, 50.0) == pytest.approx(0.1)


class TestInvariants:
    @given(P=st.floats(0, 1), alpha=st.floats(0, 1),
           lam=st.floats(0, 1), L=st.floats(0, 100),
           it=st.integers(0, 10000), ot=st.integers(0, 10000))
    @settings(max_examples=200, deadline=None)
    def test_tie_breaks_speculate(self, P, alpha, lam, L, it, ot):
        """EV >= threshold <-> SPECULATE, exactly (tie -> SPECULATE, §6.1)."""
        res = evaluate(DecisionInputs(P, alpha, lam, L, it, ot, 3e-6, 15e-6))
        want = Decision.SPECULATE if res.EV_usd >= res.threshold_usd else Decision.WAIT
        assert res.decision == want

    @given(P1=st.floats(0, 1), P2=st.floats(0, 1), alpha=st.floats(0, 1))
    @settings(max_examples=200, deadline=None)
    def test_monotone_in_p(self, P1, P2, alpha):
        """Higher P never flips SPECULATE -> WAIT (EV monotone in P)."""
        lo, hi = min(P1, P2), max(P1, P2)
        d_lo = speculation_decision(lo, alpha, 0.01, 500, 1000, 3e-6, 15e-6, 5.0)
        d_hi = speculation_decision(hi, alpha, 0.01, 500, 1000, 3e-6, 15e-6, 5.0)
        if d_lo == "SPECULATE":
            assert d_hi == "SPECULATE"

    @given(a1=st.floats(0, 1), a2=st.floats(0, 1), P=st.floats(0, 1))
    @settings(max_examples=200, deadline=None)
    def test_monotone_in_alpha(self, a1, a2, P):
        """Higher alpha (more latency-sensitive) never flips to WAIT."""
        lo, hi = min(a1, a2), max(a1, a2)
        if speculation_decision(P, lo, 0.01, 500, 1000, 3e-6, 15e-6, 5.0) == "SPECULATE":
            assert speculation_decision(P, hi, 0.01, 500, 1000, 3e-6, 15e-6, 5.0) == "SPECULATE"

    @given(P=st.floats(0.01, 0.99), alpha=st.floats(0, 1),
           L=st.floats(0.1, 100), C=st.floats(1e-6, 1.0))
    @settings(max_examples=200, deadline=None)
    def test_threshold_crossings_consistent(self, P, alpha, L, C):
        """The closed-form P crossings match the rule's behavior."""
        p_star = p_threshold_crossing(L, C, alpha)
        ev = expected_value(P, L, C)
        thr = decision_threshold(alpha, C)
        if P > min(p_star + 1e-9, 1.0) and p_star <= 1.0:
            assert ev >= thr or math.isclose(ev, thr, rel_tol=1e-6)
        assert p_break_even(L, C) <= p_threshold_crossing(L, C, alpha) + 1e-12

    @given(k=st.integers(1, 100), alpha=st.floats(0, 1))
    @settings(max_examples=200, deadline=None)
    def test_self_limiting(self, k, alpha):
        """§7.6 claim: uniform P = 1/k SPECULATEs iff k <= k_crit(alpha)."""
        kc = critical_k(AUTOREPLY_L, AUTOREPLY_C, alpha)
        d = ("SPECULATE" if expected_value(1.0 / k, AUTOREPLY_L, AUTOREPLY_C)
             >= decision_threshold(alpha, AUTOREPLY_C) else "WAIT")
        assert d == ("SPECULATE" if k <= kc else "WAIT")

    @given(P=st.floats(0.05, 1), alpha=st.floats(0, 1), L=st.floats(0.01, 100))
    @settings(max_examples=200, deadline=None)
    def test_implied_lambda_inverts_rule(self, P, alpha, L):
        """lambda_implied makes EV == threshold exactly (§12.3 closed form)."""
        C = AUTOREPLY_C
        lam = implied_lambda(P, C, alpha, L)
        ev = expected_value(P, L * lam, C)
        thr = decision_threshold(alpha, C)
        assert ev == pytest.approx(thr, rel=1e-6, abs=1e-12)


class TestValidation:
    def test_bad_alpha_rejected(self):
        with pytest.raises(ValueError):
            speculation_decision(0.5, 1.5, 0.01, 1, 1, 1e-6, 1e-6, 1.0)

    def test_bad_p_rejected(self):
        with pytest.raises(ValueError):
            expected_value(-0.1, 1.0, 1.0)
