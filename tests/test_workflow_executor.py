"""D1 + two-phase model integration tests: planner, executor, overrides,
admissibility, streaming cancellation, waste accounting."""
import pytest

from repro.core import (
    AdmissibilityTag,
    BetaPosterior,
    Decision,
    DependencyType,
    Edge,
    ExecutorConfig,
    NonSpeculableError,
    Operation,
    PlannerParams,
    Workflow,
    execute,
    plan_workflow,
)
from repro.core.predictor import HistoricalModalPredictor, TemplatePredictor
from repro.core.workflow import WorkflowError


def two_op_workflow(downstream_admissibility=AdmissibilityTag.SIDE_EFFECT_FREE,
                    chunks=10):
    wf = Workflow("doc")
    wf.add_op(Operation(
        "analyzer", run=lambda x: "topic-A", latency_est_s=5.0,
        metadata={"input": "doc1", "chunks": chunks},
    ))
    wf.add_op(Operation(
        "researcher", run=lambda t: f"research({t})", latency_est_s=5.0,
        input_tokens_est=500, output_tokens_est=1000,
        admissibility=downstream_admissibility,
    ))
    wf.add_edge(Edge("analyzer", "researcher",
                     dep_type=DependencyType.LIST_OUTPUT_VARIABLE_LENGTH))
    return wf.freeze()


def predictor_for(value="topic-A"):
    p = HistoricalModalPredictor()
    p.observe("doc1", value)
    return p


class TestWorkflow:
    def test_cycle_rejected(self):
        wf = Workflow()
        wf.add_op(Operation("a"))
        wf.add_op(Operation("b"))
        wf.add_edge(Edge("a", "b"))
        wf.add_edge(Edge("b", "a"))
        with pytest.raises(WorkflowError):
            wf.freeze()

    def test_frozen_topology_immutable(self):
        """§1.4: runtime-determined topologies are out of scope."""
        wf = two_op_workflow()
        with pytest.raises(WorkflowError):
            wf.add_op(Operation("late"))

    def test_non_speculable_filtered(self):
        """§3.3: ops failing all three admissibility routes never reach the
        EV gate."""
        wf = two_op_workflow(AdmissibilityTag.NON_SPECULABLE)
        assert wf.speculation_candidates() == []

    def test_disabled_edge_filtered(self):
        wf = Workflow()
        wf.add_op(Operation("a"))
        wf.add_op(Operation("b"))
        wf.add_edge(Edge("a", "b", enabled=False))
        wf.freeze()
        assert wf.speculation_candidates() == []


class TestPlanner:
    def test_plan_enumeration_and_objective(self):
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01)
        best, plans = plan_workflow(wf, params)
        assert len(plans) >= 2
        # parallel plan overlaps the speculated edge -> lower latency
        assert best.concurrency > 1
        assert best.expected_latency_s < 10.0
        assert best.speculated_edges() == [("analyzer", "researcher")]
        # expected waste = (1-P) * (C_in + rho*C_out)
        P = 0.7
        want = (1 - P) * (500 * 3e-6 + 0.5 * 1000 * 15e-6)
        assert best.expected_waste_usd == pytest.approx(want, rel=1e-6)

    def test_budget_constraint_marks_infeasible(self):
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01,
                               max_budget_usd=0.001)
        best, plans = plan_workflow(wf, params)
        assert all(not p.feasible for p in plans)

    def test_cost_sensitive_alpha_waits_when_p_low(self):
        wf = two_op_workflow()
        post = BetaPosterior.from_prior_mean(0.15)
        params = PlannerParams(
            alpha=0.0, lambda_usd_per_s=0.01,
            posteriors={("analyzer", "researcher"): post},
        )
        best, _ = plan_workflow(wf, params)
        assert best.speculated_edges() == []


class TestExecutor:
    def test_successful_speculation_halves_makespan(self):
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01)
        plan, _ = plan_workflow(wf, params)
        cfg = ExecutorConfig(params=params,
                             predictors={("analyzer", "researcher"): predictor_for()})
        rep = execute(wf, plan, cfg)
        assert rep.makespan_s == pytest.approx(5.0)     # full overlap
        assert rep.waste_usd == 0.0
        assert rep.outcomes[0].committed
        assert rep.outputs["researcher"] == "research(topic-A)"
        # posterior updated with the success
        assert params.posteriors[("analyzer", "researcher")].successes == 1

    def test_failed_speculation_reexecutes_with_waste(self):
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01)
        plan, _ = plan_workflow(wf, params)
        cfg = ExecutorConfig(
            params=params,
            predictors={("analyzer", "researcher"):
                        predictor_for("a completely different wrong topic zz")},
        )
        rep = execute(wf, plan, cfg)
        assert rep.makespan_s == pytest.approx(10.0)    # sequential fallback
        assert rep.waste_usd == pytest.approx(0.0165)   # full C_spec (u==v dur)
        assert not rep.outcomes[0].committed
        assert rep.outputs["researcher"] == "research(topic-A)"  # correct result
        assert params.posteriors[("analyzer", "researcher")].failures == 1

    def test_streaming_cancellation_fractional_waste(self):
        """§9: P_k collapse mid-stream -> cancel, waste < full C_spec."""
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01)
        plan, _ = plan_workflow(wf, params)

        def refine(upstream_input, partial):
            # confidence collapses at chunk 3
            return "topic-A", 0.9 if len(partial) < 3 else 0.01

        cfg = ExecutorConfig(
            params=params,
            predictors={("analyzer", "researcher"): predictor_for()},
            stream_refiners={("analyzer", "researcher"): refine},
        )
        rep = execute(wf, plan, cfg)
        o = rep.outcomes[0]
        assert o.cancelled_mid_stream
        assert 0.0 < o.waste_usd < 0.0165
        assert o.cancel_fraction is not None and o.cancel_fraction < 1.0
        # cancelled failures still count as failures for P (§10.3)
        assert params.posteriors[("analyzer", "researcher")].failures == 1

    def test_bidirectional_override_downgrade(self):
        """Plan SPECULATE -> runtime WAIT when the posterior collapses
        between phases (§8.2)."""
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01)
        plan, _ = plan_workflow(wf, params)
        assert plan.decisions[("analyzer", "researcher")].decision == Decision.SPECULATE
        # phase-2 posterior collapse
        params.posteriors[("analyzer", "researcher")] = BetaPosterior.from_prior_mean(0.05)
        cfg = ExecutorConfig(params=params,
                             predictors={("analyzer", "researcher"): predictor_for()})
        rep = execute(wf, plan, cfg)
        assert rep.overrides == [(("analyzer", "researcher"), "downgrade")]
        assert rep.outcomes == []       # no speculation launched
        assert rep.makespan_s == pytest.approx(10.0)

    def test_bidirectional_override_upgrade(self):
        """Plan WAIT -> runtime SPECULATE when alpha rises (§5.2 + §8.2)."""
        wf = two_op_workflow()
        low_p = BetaPosterior.from_prior_mean(0.25)
        params = PlannerParams(alpha=0.0, lambda_usd_per_s=0.01,
                               posteriors={("analyzer", "researcher"): low_p})
        plan, _ = plan_workflow(wf, params)
        assert plan.decisions[("analyzer", "researcher")].decision == Decision.WAIT
        cfg = ExecutorConfig(
            params=params,
            predictors={("analyzer", "researcher"): predictor_for()},
            alpha_fn=lambda t: 1.0,     # operator went latency-sensitive
        )
        rep = execute(wf, plan, cfg)
        assert rep.overrides == [(("analyzer", "researcher"), "upgrade")]
        assert rep.outcomes and rep.outcomes[0].launched

    def test_commit_barrier_staged_effects(self):
        """§3.3 route 3: effects released only after tier pass, dropped on
        failure."""
        released = []
        wf = Workflow("barrier")
        wf.add_op(Operation("u", run=lambda x: "right", latency_est_s=2.0,
                            metadata={"input": "q"}))
        wf.add_op(Operation(
            "v", run=lambda t: f"draft({t})", latency_est_s=2.0,
            admissibility=AdmissibilityTag.COMMIT_BARRIER,
            metadata={"effect": released.append},
        ))
        wf.add_edge(Edge("u", "v"))
        wf.freeze()
        params = PlannerParams(alpha=1.0, lambda_usd_per_s=0.05)
        plan, _ = plan_workflow(wf, params)
        cfg = ExecutorConfig(params=params,
                             predictors={("u", "v"): predictor_for_value("q", "right")})
        rep = execute(wf, plan, cfg)
        assert rep.outcomes[0].committed
        assert released == ["draft(right)"]
        # failure path: staged effect dropped, only re-executed one released
        released.clear()
        cfg2 = ExecutorConfig(params=PlannerParams(alpha=1.0, lambda_usd_per_s=0.05),
                              predictors={("u", "v"): predictor_for_value("q", "wrong-aaa-bbb")})
        plan2, _ = plan_workflow(wf, cfg2.params)
        rep2 = execute(wf, plan2, cfg2)
        assert not rep2.outcomes[0].committed
        assert released == ["draft(right)"]

    def test_telemetry_rows_emitted(self):
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01)
        plan, _ = plan_workflow(wf, params)
        cfg = ExecutorConfig(params=params,
                             predictors={("analyzer", "researcher"): predictor_for()})
        rep = execute(wf, plan, cfg)
        assert len(cfg.telemetry) == 1
        row = cfg.telemetry.rows[0]
        assert row.decision == "SPECULATE"
        assert row.phase == "runtime"
        assert row.committed_speculative is True
        assert row.i_actual == "topic-A"
        assert row.tier1_match is True


def predictor_for_value(inp, value):
    p = HistoricalModalPredictor()
    p.observe(inp, value)
    return p


class TestDiamondDag:
    def test_multi_parent_speculation(self):
        """v with two parents: speculate against the late parent only."""
        wf = Workflow("diamond")
        wf.add_op(Operation("src", run=lambda x: "S", latency_est_s=1.0,
                            metadata={"input": "go"}))
        wf.add_op(Operation("fast", run=lambda s: "F", latency_est_s=1.0))
        wf.add_op(Operation("slow", run=lambda s: "W", latency_est_s=6.0))
        wf.add_op(Operation("join", run=lambda a, b: f"{a}+{b}", latency_est_s=3.0))
        wf.add_edge(Edge("src", "fast"))
        wf.add_edge(Edge("src", "slow"))
        wf.add_edge(Edge("fast", "join", enabled=False))
        wf.add_edge(Edge("slow", "join",
                         dep_type=DependencyType.ALWAYS_PRODUCES_OUTPUT))
        wf.freeze()
        params = PlannerParams(alpha=1.0, lambda_usd_per_s=0.05)
        plan, _ = plan_workflow(wf, params)
        pred = HistoricalModalPredictor()
        pred.observe(None, "W")
        cfg = ExecutorConfig(params=params, predictors={("slow", "join"): pred})
        rep = execute(wf, plan, cfg)
        assert rep.outputs["join"] in ("W+F", "F+W") or "+" in rep.outputs["join"]
        # sequential would be 1 + 6 + 3 = 10; overlap saves the join time
        assert rep.makespan_s < 10.0


class TestPlannerConsistency:
    """Regression suite for the planner determinism / consistency sweep:
    multi-spec-parent schedule order, sequential decision downgrades,
    multi-constraint infeasibility labels, the max_concurrency=0 trap,
    and least-violating infeasible plan selection."""

    @staticmethod
    def _two_parent_join(edge_order):
        """a and b both feed join over speculation edges; ``edge_order``
        permutes insertion so dict/set iteration order differs."""
        wf = Workflow("join2")
        wf.add_op(Operation("a", run=lambda x: "A", latency_est_s=4.0,
                            metadata={"input": "go"}))
        wf.add_op(Operation("b", run=lambda x: "B", latency_est_s=6.0,
                            metadata={"input": "go"}))
        wf.add_op(Operation("join", run=lambda a, b: f"{a}+{b}",
                            latency_est_s=3.0, input_tokens_est=500,
                            output_tokens_est=1000))
        for u in edge_order:
            wf.add_edge(Edge(u, "join",
                             dep_type=DependencyType.LIST_OUTPUT_VARIABLE_LENGTH))
        return wf.freeze()

    def test_two_spec_parent_schedule_is_order_independent(self):
        """The expected-finish mix over several speculated parents must
        not depend on spec-edge iteration order (it used to read
        next(iter(spec_parents)) — whichever parent hash order served
        first)."""
        lats, wastes = [], []
        for order in (("a", "b"), ("b", "a")):
            wf = self._two_parent_join(order)
            params = PlannerParams(alpha=0.9, lambda_usd_per_s=0.05)
            best, _ = plan_workflow(wf, params)
            assert sorted(best.speculated_edges()) == [
                ("a", "join"), ("b", "join")]
            lats.append(best.expected_latency_s)
            wastes.append(best.expected_waste_usd)
        assert lats[0] == lats[1]        # bitwise: same sorted product
        assert wastes[0] == wastes[1]

    def test_two_spec_parent_expected_finish_closed_form(self):
        """Joint commit needs both predictions (P = product); both the
        verify and re-execute paths wait for the later parent."""
        wf = self._two_parent_join(("a", "b"))
        params = PlannerParams(alpha=0.9, lambda_usd_per_s=0.05)
        best, _ = plan_workflow(wf, params)
        P = 0.7 * 0.7                    # both LIST_OUTPUT priors
        spec_finish = 6.0                # the later parent (b)
        want = P * max(0.0 + 3.0, spec_finish) + (1 - P) * (spec_finish + 3.0)
        assert best.schedule["join"].finish_s == pytest.approx(want)
        assert best.expected_latency_s == pytest.approx(want)

    def test_sequential_plan_downgrades_decision_records(self):
        """concurrency=1 cannot overlap: the SPECULATE records must be
        downgraded (not silently left contradicting the schedule), with
        the override reason recorded."""
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01,
                               max_concurrency=1)
        best, plans = plan_workflow(wf, params)
        assert [p.concurrency for p in plans] == [1]
        assert best.speculated_edges() == []
        assert best.decisions[("analyzer", "researcher")].decision == Decision.WAIT
        assert best.schedule_overrides == {
            ("analyzer", "researcher"): "sequential"}
        assert best.expected_waste_usd == 0.0      # nothing launched
        assert best.expected_latency_s == pytest.approx(10.0)
        # a parallel plan on the same workflow keeps its SPECULATE record
        free, _ = plan_workflow(wf, PlannerParams(alpha=0.5,
                                                  lambda_usd_per_s=0.01))
        assert free.schedule_overrides == {}
        assert free.speculated_edges() == [("analyzer", "researcher")]

    def test_infeasibility_reports_every_violated_constraint(self):
        """Both constraints violated -> "budget+latency", not whichever
        check happened to run last."""
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.0, lambda_usd_per_s=0.01,
                               max_budget_usd=0.001, max_latency_s=8.0)
        _, plans = plan_workflow(wf, params)
        seq = next(p for p in plans if p.concurrency == 1)
        par = next(p for p in plans if p.concurrency > 1)
        assert not seq.feasible and seq.infeasibility == "budget+latency"
        assert not par.feasible and par.infeasibility == "budget"

    def test_max_concurrency_zero_raises(self):
        """0 used to be swallowed by ``or`` into "unbounded"."""
        wf = two_op_workflow()
        with pytest.raises(ValueError):
            plan_workflow(wf, PlannerParams(max_concurrency=0))
        with pytest.raises(ValueError):
            plan_workflow(wf, PlannerParams(max_concurrency=-2))

    def test_least_violating_plan_wins_when_all_infeasible(self):
        """With every plan infeasible, return the smallest USD-priced
        constraint overshoot — not the minimum objective (which ignores
        the constraints entirely and picked the *worst* violator here)."""
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.0, lambda_usd_per_s=0.01,
                               max_budget_usd=0.001, max_latency_s=8.0)
        best, plans = plan_workflow(wf, params)
        assert all(not p.feasible for p in plans)
        # min-objective (alpha=0 -> pure cost) is the sequential plan...
        by_obj = min(plans, key=lambda p: p.objective(0.0, 0.01))
        assert by_obj.concurrency == 1
        # ...but the parallel plan violates less in USD terms
        assert best.concurrency > 1
        assert best.infeasibility == "budget"

    def test_beam_planner_path(self):
        """PlannerParams.beam_confidences routes the edge through the
        beam gate: the decision carries candidate bookkeeping and the
        waste uses the beam form over launched candidates."""
        from repro.core import beam_evaluate, expected_beam_waste
        from repro.core.decision import DecisionInputs
        from repro.core.pricing import TwoRateTokenCost

        wf = two_op_workflow()
        key = ("analyzer", "researcher")
        confs = (0.6, 0.3)
        params = PlannerParams(alpha=0.9, lambda_usd_per_s=0.05,
                               beam_width=2,
                               beam_confidences={key: confs})
        best, _ = plan_workflow(wf, params)
        d = best.decisions[key]
        assert d.decision == Decision.SPECULATE
        assert d.width == 2 and d.w_eff == 2 and d.launched == 2
        # the gate is the scalar beam rule on the edge's posterior mean
        post = params.posteriors[key]
        ref = beam_evaluate(
            DecisionInputs(P=post.mean, alpha=0.9, lambda_usd_per_s=0.05,
                           latency_seconds=5.0, input_tokens=500,
                           output_tokens=1000, input_price=3e-6,
                           output_price=15e-6),
            confs, 2)
        assert d.EV_usd == ref.EV_usd and d.P_used == ref.P_used
        p_cum = sum(confs) * post.mean
        want = expected_beam_waste(p_cum, 2, TwoRateTokenCost(3e-6, 15e-6),
                                   500, 1000)
        assert best.expected_waste_usd == pytest.approx(want, rel=1e-12)
        # schedule uses the beam-cumulative commit probability
        want_finish = p_cum * 5.0 + (1 - p_cum) * 10.0
        assert best.expected_latency_s == pytest.approx(want_finish)


class TestFractionalWaste:
    def test_bills_actuals_past_the_plan(self):
        """Regression for the dead clamp in streaming.fractional_waste: the
        planned-token reassignment was never read — billing is (and now
        explicitly documents being) on the actuals, including generation
        that ran past the plan before the cancel landed."""
        from repro.core import fractional_waste
        from repro.core.pricing import TwoRateTokenCost

        cm = TwoRateTokenCost(3e-6, 15e-6)
        base = fractional_waste(cm, 400, 900, 900.0)
        over = fractional_waste(cm, 400, 900, 1100.0)   # ran past the plan
        assert over == pytest.approx(400 * 3e-6 + 1100 * 15e-6)
        assert over > base
        # plan figure does not affect the bill
        assert fractional_waste(cm, 400, 1, 1100.0) == over

    def test_rejects_negative_token_counts(self):
        from repro.core import fractional_waste
        from repro.core.pricing import TwoRateTokenCost

        cm = TwoRateTokenCost(3e-6, 15e-6)
        for bad in [(-1, 900, 100.0), (400, -1.0, 100.0), (400, 900, -0.5)]:
            with pytest.raises(ValueError):
                fractional_waste(cm, *bad)
