"""D1 + two-phase model integration tests: planner, executor, overrides,
admissibility, streaming cancellation, waste accounting."""
import pytest

from repro.core import (
    AdmissibilityTag,
    BetaPosterior,
    Decision,
    DependencyType,
    Edge,
    ExecutorConfig,
    NonSpeculableError,
    Operation,
    PlannerParams,
    Workflow,
    execute,
    plan_workflow,
)
from repro.core.predictor import HistoricalModalPredictor, TemplatePredictor
from repro.core.workflow import WorkflowError


def two_op_workflow(downstream_admissibility=AdmissibilityTag.SIDE_EFFECT_FREE,
                    chunks=10):
    wf = Workflow("doc")
    wf.add_op(Operation(
        "analyzer", run=lambda x: "topic-A", latency_est_s=5.0,
        metadata={"input": "doc1", "chunks": chunks},
    ))
    wf.add_op(Operation(
        "researcher", run=lambda t: f"research({t})", latency_est_s=5.0,
        input_tokens_est=500, output_tokens_est=1000,
        admissibility=downstream_admissibility,
    ))
    wf.add_edge(Edge("analyzer", "researcher",
                     dep_type=DependencyType.LIST_OUTPUT_VARIABLE_LENGTH))
    return wf.freeze()


def predictor_for(value="topic-A"):
    p = HistoricalModalPredictor()
    p.observe("doc1", value)
    return p


class TestWorkflow:
    def test_cycle_rejected(self):
        wf = Workflow()
        wf.add_op(Operation("a"))
        wf.add_op(Operation("b"))
        wf.add_edge(Edge("a", "b"))
        wf.add_edge(Edge("b", "a"))
        with pytest.raises(WorkflowError):
            wf.freeze()

    def test_frozen_topology_immutable(self):
        """§1.4: runtime-determined topologies are out of scope."""
        wf = two_op_workflow()
        with pytest.raises(WorkflowError):
            wf.add_op(Operation("late"))

    def test_non_speculable_filtered(self):
        """§3.3: ops failing all three admissibility routes never reach the
        EV gate."""
        wf = two_op_workflow(AdmissibilityTag.NON_SPECULABLE)
        assert wf.speculation_candidates() == []

    def test_disabled_edge_filtered(self):
        wf = Workflow()
        wf.add_op(Operation("a"))
        wf.add_op(Operation("b"))
        wf.add_edge(Edge("a", "b", enabled=False))
        wf.freeze()
        assert wf.speculation_candidates() == []


class TestPlanner:
    def test_plan_enumeration_and_objective(self):
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01)
        best, plans = plan_workflow(wf, params)
        assert len(plans) >= 2
        # parallel plan overlaps the speculated edge -> lower latency
        assert best.concurrency > 1
        assert best.expected_latency_s < 10.0
        assert best.speculated_edges() == [("analyzer", "researcher")]
        # expected waste = (1-P) * (C_in + rho*C_out)
        P = 0.7
        want = (1 - P) * (500 * 3e-6 + 0.5 * 1000 * 15e-6)
        assert best.expected_waste_usd == pytest.approx(want, rel=1e-6)

    def test_budget_constraint_marks_infeasible(self):
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01,
                               max_budget_usd=0.001)
        best, plans = plan_workflow(wf, params)
        assert all(not p.feasible for p in plans)

    def test_cost_sensitive_alpha_waits_when_p_low(self):
        wf = two_op_workflow()
        post = BetaPosterior.from_prior_mean(0.15)
        params = PlannerParams(
            alpha=0.0, lambda_usd_per_s=0.01,
            posteriors={("analyzer", "researcher"): post},
        )
        best, _ = plan_workflow(wf, params)
        assert best.speculated_edges() == []


class TestExecutor:
    def test_successful_speculation_halves_makespan(self):
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01)
        plan, _ = plan_workflow(wf, params)
        cfg = ExecutorConfig(params=params,
                             predictors={("analyzer", "researcher"): predictor_for()})
        rep = execute(wf, plan, cfg)
        assert rep.makespan_s == pytest.approx(5.0)     # full overlap
        assert rep.waste_usd == 0.0
        assert rep.outcomes[0].committed
        assert rep.outputs["researcher"] == "research(topic-A)"
        # posterior updated with the success
        assert params.posteriors[("analyzer", "researcher")].successes == 1

    def test_failed_speculation_reexecutes_with_waste(self):
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01)
        plan, _ = plan_workflow(wf, params)
        cfg = ExecutorConfig(
            params=params,
            predictors={("analyzer", "researcher"):
                        predictor_for("a completely different wrong topic zz")},
        )
        rep = execute(wf, plan, cfg)
        assert rep.makespan_s == pytest.approx(10.0)    # sequential fallback
        assert rep.waste_usd == pytest.approx(0.0165)   # full C_spec (u==v dur)
        assert not rep.outcomes[0].committed
        assert rep.outputs["researcher"] == "research(topic-A)"  # correct result
        assert params.posteriors[("analyzer", "researcher")].failures == 1

    def test_streaming_cancellation_fractional_waste(self):
        """§9: P_k collapse mid-stream -> cancel, waste < full C_spec."""
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01)
        plan, _ = plan_workflow(wf, params)

        def refine(upstream_input, partial):
            # confidence collapses at chunk 3
            return "topic-A", 0.9 if len(partial) < 3 else 0.01

        cfg = ExecutorConfig(
            params=params,
            predictors={("analyzer", "researcher"): predictor_for()},
            stream_refiners={("analyzer", "researcher"): refine},
        )
        rep = execute(wf, plan, cfg)
        o = rep.outcomes[0]
        assert o.cancelled_mid_stream
        assert 0.0 < o.waste_usd < 0.0165
        assert o.cancel_fraction is not None and o.cancel_fraction < 1.0
        # cancelled failures still count as failures for P (§10.3)
        assert params.posteriors[("analyzer", "researcher")].failures == 1

    def test_bidirectional_override_downgrade(self):
        """Plan SPECULATE -> runtime WAIT when the posterior collapses
        between phases (§8.2)."""
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01)
        plan, _ = plan_workflow(wf, params)
        assert plan.decisions[("analyzer", "researcher")].decision == Decision.SPECULATE
        # phase-2 posterior collapse
        params.posteriors[("analyzer", "researcher")] = BetaPosterior.from_prior_mean(0.05)
        cfg = ExecutorConfig(params=params,
                             predictors={("analyzer", "researcher"): predictor_for()})
        rep = execute(wf, plan, cfg)
        assert rep.overrides == [(("analyzer", "researcher"), "downgrade")]
        assert rep.outcomes == []       # no speculation launched
        assert rep.makespan_s == pytest.approx(10.0)

    def test_bidirectional_override_upgrade(self):
        """Plan WAIT -> runtime SPECULATE when alpha rises (§5.2 + §8.2)."""
        wf = two_op_workflow()
        low_p = BetaPosterior.from_prior_mean(0.25)
        params = PlannerParams(alpha=0.0, lambda_usd_per_s=0.01,
                               posteriors={("analyzer", "researcher"): low_p})
        plan, _ = plan_workflow(wf, params)
        assert plan.decisions[("analyzer", "researcher")].decision == Decision.WAIT
        cfg = ExecutorConfig(
            params=params,
            predictors={("analyzer", "researcher"): predictor_for()},
            alpha_fn=lambda t: 1.0,     # operator went latency-sensitive
        )
        rep = execute(wf, plan, cfg)
        assert rep.overrides == [(("analyzer", "researcher"), "upgrade")]
        assert rep.outcomes and rep.outcomes[0].launched

    def test_commit_barrier_staged_effects(self):
        """§3.3 route 3: effects released only after tier pass, dropped on
        failure."""
        released = []
        wf = Workflow("barrier")
        wf.add_op(Operation("u", run=lambda x: "right", latency_est_s=2.0,
                            metadata={"input": "q"}))
        wf.add_op(Operation(
            "v", run=lambda t: f"draft({t})", latency_est_s=2.0,
            admissibility=AdmissibilityTag.COMMIT_BARRIER,
            metadata={"effect": released.append},
        ))
        wf.add_edge(Edge("u", "v"))
        wf.freeze()
        params = PlannerParams(alpha=1.0, lambda_usd_per_s=0.05)
        plan, _ = plan_workflow(wf, params)
        cfg = ExecutorConfig(params=params,
                             predictors={("u", "v"): predictor_for_value("q", "right")})
        rep = execute(wf, plan, cfg)
        assert rep.outcomes[0].committed
        assert released == ["draft(right)"]
        # failure path: staged effect dropped, only re-executed one released
        released.clear()
        cfg2 = ExecutorConfig(params=PlannerParams(alpha=1.0, lambda_usd_per_s=0.05),
                              predictors={("u", "v"): predictor_for_value("q", "wrong-aaa-bbb")})
        plan2, _ = plan_workflow(wf, cfg2.params)
        rep2 = execute(wf, plan2, cfg2)
        assert not rep2.outcomes[0].committed
        assert released == ["draft(right)"]

    def test_telemetry_rows_emitted(self):
        wf = two_op_workflow()
        params = PlannerParams(alpha=0.5, lambda_usd_per_s=0.01)
        plan, _ = plan_workflow(wf, params)
        cfg = ExecutorConfig(params=params,
                             predictors={("analyzer", "researcher"): predictor_for()})
        rep = execute(wf, plan, cfg)
        assert len(cfg.telemetry) == 1
        row = cfg.telemetry.rows[0]
        assert row.decision == "SPECULATE"
        assert row.phase == "runtime"
        assert row.committed_speculative is True
        assert row.i_actual == "topic-A"
        assert row.tier1_match is True


def predictor_for_value(inp, value):
    p = HistoricalModalPredictor()
    p.observe(inp, value)
    return p


class TestDiamondDag:
    def test_multi_parent_speculation(self):
        """v with two parents: speculate against the late parent only."""
        wf = Workflow("diamond")
        wf.add_op(Operation("src", run=lambda x: "S", latency_est_s=1.0,
                            metadata={"input": "go"}))
        wf.add_op(Operation("fast", run=lambda s: "F", latency_est_s=1.0))
        wf.add_op(Operation("slow", run=lambda s: "W", latency_est_s=6.0))
        wf.add_op(Operation("join", run=lambda a, b: f"{a}+{b}", latency_est_s=3.0))
        wf.add_edge(Edge("src", "fast"))
        wf.add_edge(Edge("src", "slow"))
        wf.add_edge(Edge("fast", "join", enabled=False))
        wf.add_edge(Edge("slow", "join",
                         dep_type=DependencyType.ALWAYS_PRODUCES_OUTPUT))
        wf.freeze()
        params = PlannerParams(alpha=1.0, lambda_usd_per_s=0.05)
        plan, _ = plan_workflow(wf, params)
        pred = HistoricalModalPredictor()
        pred.observe(None, "W")
        cfg = ExecutorConfig(params=params, predictors={("slow", "join"): pred})
        rep = execute(wf, plan, cfg)
        assert rep.outputs["join"] in ("W+F", "F+W") or "+" in rep.outputs["join"]
        # sequential would be 1 + 6 + 3 = 10; overlap saves the join time
        assert rep.makespan_s < 10.0


class TestFractionalWaste:
    def test_bills_actuals_past_the_plan(self):
        """Regression for the dead clamp in streaming.fractional_waste: the
        planned-token reassignment was never read — billing is (and now
        explicitly documents being) on the actuals, including generation
        that ran past the plan before the cancel landed."""
        from repro.core import fractional_waste
        from repro.core.pricing import TwoRateTokenCost

        cm = TwoRateTokenCost(3e-6, 15e-6)
        base = fractional_waste(cm, 400, 900, 900.0)
        over = fractional_waste(cm, 400, 900, 1100.0)   # ran past the plan
        assert over == pytest.approx(400 * 3e-6 + 1100 * 15e-6)
        assert over > base
        # plan figure does not affect the bill
        assert fractional_waste(cm, 400, 1, 1100.0) == over

    def test_rejects_negative_token_counts(self):
        from repro.core import fractional_waste
        from repro.core.pricing import TwoRateTokenCost

        cm = TwoRateTokenCost(3e-6, 15e-6)
        for bad in [(-1, 900, 100.0), (400, -1.0, 100.0), (400, 900, -0.5)]:
            with pytest.raises(ValueError):
                fractional_waste(cm, *bad)
