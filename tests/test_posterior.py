"""D5 Beta-Binomial posterior + taxonomy tests (paper App. A/B tables)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.posterior import BetaPosterior
from repro.core.taxonomy import (
    DependencyType,
    auto_assign,
    effective_k,
    prior_params,
    structural_prior,
)


class TestTaxonomy:
    def test_prior_table(self):
        """§7.2 prior means + App. A.3 (alpha0, beta0) verification table."""
        assert structural_prior(DependencyType.ALWAYS_PRODUCES_OUTPUT) == 0.9
        assert structural_prior(DependencyType.LIST_OUTPUT_VARIABLE_LENGTH) == 0.7
        assert structural_prior(DependencyType.CONDITIONAL_OUTPUT) == 0.5
        assert structural_prior(DependencyType.ROUTER_K_WAY, k=3) == pytest.approx(1 / 3)
        assert prior_params(DependencyType.ALWAYS_PRODUCES_OUTPUT) == pytest.approx((1.8, 0.2))
        assert prior_params(DependencyType.LIST_OUTPUT_VARIABLE_LENGTH) == pytest.approx((1.4, 0.6))
        assert prior_params(DependencyType.CONDITIONAL_OUTPUT) == pytest.approx((1.0, 1.0))
        a0, b0 = prior_params(DependencyType.ROUTER_K_WAY, k=3)
        assert (a0, b0) == pytest.approx((0.667, 1.333), abs=1e-3)

    def test_rare_event_range_enforced(self):
        assert 0.1 <= structural_prior(DependencyType.RARE_EVENT_TRIGGER) <= 0.2
        with pytest.raises(ValueError):
            structural_prior(DependencyType.RARE_EVENT_TRIGGER, rare_event_p=0.5)

    def test_effective_k(self):
        """§7.6: 5-way classifier, 62% mode -> k_eff ~ 1.6."""
        outputs = ["billing"] * 62 + ["support"] * 12 + ["sales"] * 10 + \
            ["spam"] * 9 + ["other"] * 7
        ek = effective_k(outputs)
        assert ek.k_raw == 5
        assert ek.p_mode == pytest.approx(0.62)
        assert ek.k_eff == pytest.approx(1.6, abs=0.05)

    def test_auto_assign_rules(self):
        """§12.1 auto-assignment."""
        assert auto_assign(["a"] * 90 + ["b"] * 10) == DependencyType.ALWAYS_PRODUCES_OUTPUT
        assert auto_assign([["t1", "t2"], ["t3"]] * 10) == DependencyType.LIST_OUTPUT_VARIABLE_LENGTH
        assert auto_assign(["a", "b", "c"] * 20) == DependencyType.ROUTER_K_WAY
        many = [f"o{i}" for i in range(10)] * 3 + [f"u{i}" for i in range(15)]
        assert auto_assign(many) in (DependencyType.RARE_EVENT_TRIGGER,
                                     DependencyType.CONDITIONAL_OUTPUT)


class TestPosterior:
    def test_appendix_a4_worked_example(self):
        """App. A.4: list_output prior, S S F S then 5 successes."""
        p = BetaPosterior.from_dependency_type(DependencyType.LIST_OUTPUT_VARIABLE_LENGTH)
        assert (p.alpha, p.beta) == pytest.approx((1.4, 0.6))
        assert p.mean == pytest.approx(0.700)
        means = []
        for outcome in (True, True, False, True):
            means.append(p.update(outcome).mean)
        assert means == pytest.approx([0.800, 0.850, 0.680, 0.733], abs=1e-3)
        p.update_batch(5, 0)
        assert p.mean == pytest.approx(0.855, abs=1e-3)
        assert p.data_weight() == pytest.approx(0.82, abs=0.01)

    def test_appendix_b_router_example(self):
        """App. B: k=3 router, routes B C B D B."""
        p = BetaPosterior.from_dependency_type(DependencyType.ROUTER_K_WAY, k=3)
        assert p.mean == pytest.approx(0.333, abs=1e-3)
        seq = [True, False, True, False, True]
        expected = [0.556, 0.417, 0.533, 0.444, 0.524]
        for outcome, want in zip(seq, expected):
            assert p.update(outcome).mean == pytest.approx(want, abs=1e-3)

    def test_appendix_a5_credible_bounds(self):
        """App. A.5: same mean 0.85, very different 10% lower bounds."""
        mature = BetaPosterior(alpha=85, beta=15)
        cold = BetaPosterior(alpha=1.7, beta=0.3)
        assert mature.mean == pytest.approx(0.85)
        assert cold.mean == pytest.approx(0.85)
        assert mature.lower_bound(0.1) == pytest.approx(0.803, abs=5e-3)
        # Paper prints 0.325 for Beta(1.7, 0.3); the actual 10% quantile is
        # 0.530 (scipy betaincinv) — paper inconsistency #4 (DESIGN.md).
        # The qualitative §7.5 claim (cold-start bound far below mature,
        # wide uncertainty) holds either way:
        assert cold.lower_bound(0.1) == pytest.approx(0.530, abs=5e-3)
        assert cold.lower_bound(0.1) < mature.lower_bound(0.1)
        assert (cold.credible_interval(0.95)[1]
                - cold.credible_interval(0.95)[0]) > 0.3

    def test_section_10_2_update(self):
        """§10.2: posterior 4.4/6.0 then two failures -> 0.55."""
        p = BetaPosterior(alpha=4.4, beta=1.6)
        assert p.mean == pytest.approx(0.733, abs=1e-3)
        p.update(False).update(False)
        assert (p.alpha, p.beta) == pytest.approx((4.4, 3.6))
        assert p.mean == pytest.approx(0.55)

    def test_data_seeding(self):
        """§12.1 data-seeded prior opens near truth."""
        p = BetaPosterior.data_seeded(DependencyType.CONDITIONAL_OUTPUT, 80, 20)
        assert p.mean == pytest.approx((1 + 80) / (2 + 100), abs=1e-6)

    @given(st.lists(st.booleans(), min_size=1, max_size=200))
    @settings(max_examples=100, deadline=None)
    def test_conjugacy(self, outcomes):
        """Sequential updates == batch update (conjugate bookkeeping)."""
        p1 = BetaPosterior.from_prior_mean(0.5)
        p2 = BetaPosterior.from_prior_mean(0.5)
        for o in outcomes:
            p1.update(o)
        p2.update_batch(sum(outcomes), len(outcomes) - sum(outcomes))
        assert p1.mean == pytest.approx(p2.mean)
        assert p1.alpha == pytest.approx(p2.alpha)

    @given(st.floats(0.05, 0.95), st.integers(1, 500))
    @settings(max_examples=50, deadline=None)
    def test_lower_bound_below_mean(self, prior, n):
        p = BetaPosterior.from_prior_mean(prior)
        p.update_batch(n // 2, n - n // 2)
        assert p.lower_bound(0.1) <= p.mean + 1e-12

    def test_convergence_d3(self):
        """App. D.3: Beta(1,1), P_true=0.62, 200 obs -> mean near truth,
        95% CI ~ [0.53, 0.67] at the paper's seed."""
        rng = np.random.default_rng(20260531)
        p = BetaPosterior.from_dependency_type(DependencyType.CONDITIONAL_OUTPUT)
        draws = rng.random(200) < 0.62
        for d in draws:
            p.update(bool(d))
        assert abs(p.mean - 0.62) < 0.07
        lo, hi = p.credible_interval(0.95)
        assert hi - lo < 0.16
        assert lo < 0.62 < hi

    def test_update_batch_applies_discount(self):
        """Regression: update_batch on a discount<1 posterior used to apply
        the undiscounted conjugate update, silently diverging from
        update/update_many.  It must now follow the same sequential
        forgetting recurrence — successes first, then failures — exactly."""
        for d in (0.95, 0.5):
            for s, f in [(0, 0), (5, 0), (0, 4), (7, 3), (1, 1)]:
                batch = BetaPosterior.from_prior_mean(0.6, discount=d)
                seq = BetaPosterior.from_prior_mean(0.6, discount=d)
                batch.update_batch(s, f)
                seq.update_many([True] * s + [False] * f)
                assert batch.alpha == seq.alpha     # bitwise, same recurrence
                assert batch.beta == seq.beta
                assert (batch.successes, batch.failures) == (s, f)
        # discount=1 keeps the closed-form conjugate fast path
        p = BetaPosterior.from_prior_mean(0.6)
        p.update_batch(3, 2)
        assert p.alpha == pytest.approx(1.2 + 3) and p.beta == pytest.approx(0.8 + 2)
        with pytest.raises(ValueError):
            p.update_batch(-1, 0)

    def test_discounted_update_responds_faster(self):
        """§14.3 exponential forgetting: after a regime shift the discounted
        posterior moves toward the new rate faster."""
        exact = BetaPosterior.from_prior_mean(0.5)
        disc = BetaPosterior.from_prior_mean(0.5, discount=0.95)
        for _ in range(100):
            exact.update(True)
            disc.update(True)
        for _ in range(30):
            exact.update(False)
            disc.update(False)
        assert disc.mean < exact.mean  # responded faster to the shift
