"""Staged-rollout lifecycle benchmark: gates first, Pareto table second.

The rollout controller's claims are behavioral, so the gates come before
any timing (repo discipline — parity before timing):

* **scenario determinism** — the same seeded scenario replays the same
  transition fingerprint, twice;
* **lifecycle parity** — the in-graph phase machine's transitions and
  its (phase, cooldown, probes, ticks, n, s) columns match a pure-Python
  scalar reference lifecycle, tick for tick, bitwise, on an adversarial
  flip trace;
* **zero recompile** — phase churn (promote/demote/re-enter, config in
  hand) never compiles a new tick executable: ``_tick._cache_size()`` is
  flat across the storm;
* **acceptance flip** — the issue's end-to-end criterion: a seeded
  sudden drift flip at a known tick, driven through
  frontend → injector → rollout → service, demotes the row within the
  detector's trigger window, bills the demotion in USD, and re-promotes
  through cooldown + probes once the trace reverts.

Then the eight-archetype scenario fleet runs and the per-archetype
Pareto table (speculate share vs. observed success vs. lifecycle
outcome) is published to ``BENCH_rollout.json``.  ``--smoke`` runs
everything with ``decisions_per_s == 0.0`` and writes nothing.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

BENCH_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_rollout.json"

SEED = 0
# flip onset -> first demote must land within this many ticks (posterior
# decay through the credible floor + the detector's consecutive-N)
TRIGGER_WINDOW_TICKS = 20


# --------------------------------------------------------------------------
# gate 1: scenario determinism
# --------------------------------------------------------------------------
def assert_scenario_determinism() -> dict:
    """Same Scenario + seed -> identical transition fingerprints and
    event counts, run twice from scratch."""
    from repro.serving.scenarios import adversarial_scenarios, run_scenario

    checked = 0
    for sc in adversarial_scenarios(SEED)[:3]:
        a, b = run_scenario(sc), run_scenario(sc)
        if a.signature() != b.signature() or a.events != b.events:
            raise AssertionError(f"{sc.name}: replay diverged")
        checked += 1
    return {"deterministic": True, "scenarios_checked": checked}


# --------------------------------------------------------------------------
# gate 2: in-graph vs scalar lifecycle parity
# --------------------------------------------------------------------------
def assert_lifecycle_parity(ticks: int = 140) -> dict:
    """Drive the controller and the pure-Python ``ReferenceLifecycle``
    through the same flip/revert trace and the same trigger masks; every
    tick's packed transition codes and the full roll state must match
    exactly (integer state — no tolerance)."""
    from repro.core.online import OnlineDecisionService
    from repro.core.posterior import BetaPosterior
    from repro.core.rollout import (ReferenceLifecycle, RolloutConfig,
                                    RolloutController)
    from repro.serving.faults import DriftTrace, FaultInjector, FaultPlan

    svc = OnlineDecisionService(credible_consecutive_n=3)
    svc.register_edge(("a", "b"), tenant="t0",
                      posterior=BetaPosterior(alpha=16.0, beta=2.0),
                      discount=0.9, floor_alpha=0.3,
                      floor_C_spec_usd=1.0, floor_L_value_usd=1.0)
    cfg = RolloutConfig(cooldown_ticks=6, probe_budget=4, min_obs=(3, 3, 3))
    ctl = RolloutController(svc, cfg)
    ref = ReferenceLifecycle(1, cfg)
    inj = FaultInjector(FaultPlan(
        trace=DriftTrace.flip(20, rate1=0.02, revert_at=55), seed=7))
    n_trans = 0
    for _ in range(ticks):
        ok = inj.outcome()
        d = ctl.tick([0], alpha=0.5, lambda_usd_per_s=0.9, latency_s=3.0,
                     input_tokens=500, output_tokens=300,
                     input_price=3e-6, output_price=15e-6,
                     outcomes=[(0, ok)])
        ref_out = ref.tick([0], {0: (1, 1 if ok else 0)},
                           np.flatnonzero(d.drift_triggered))
        dev = {int(r): int(c)
               for r, c in enumerate(d.rollout_transitions) if c}
        if dev != ref_out:
            raise AssertionError(
                f"transition mismatch: device {dev} != scalar {ref_out}")
        n_trans += len(dev)
        got = np.asarray(svc.store.roll_snapshot()[0])
        want = np.asarray(ref.rows[0], np.int32)
        if not np.array_equal(got, want):
            raise AssertionError(
                f"roll state mismatch: device {got} != scalar {want}")
    if n_trans < 6:
        raise AssertionError(
            f"parity trace exercised only {n_trans} transitions")
    return {"in_graph_vs_scalar_lifecycle": True, "ticks": ticks,
            "transitions": n_trans, "roll_state_bitwise": True}


# --------------------------------------------------------------------------
# gate 3: zero recompile across phase churn
# --------------------------------------------------------------------------
def assert_zero_recompile(ticks: int = 90) -> dict:
    """Promotions, demotions, cooldowns and re-entries are all operand
    churn: after the two tick executables warm up (settle-free and
    packed-outcome), the jit cache must not grow while the lifecycle
    storms through every phase."""
    from repro.core import online as online_mod
    from repro.core.online import OnlineDecisionService
    from repro.core.posterior import BetaPosterior
    from repro.core.rollout import RolloutConfig, RolloutController
    from repro.serving.faults import DriftTrace, FaultInjector, FaultPlan

    svc = OnlineDecisionService(credible_consecutive_n=3)
    for r in range(4):
        svc.register_edge((f"a{r}", f"b{r}"), tenant=f"t{r % 2}",
                          posterior=BetaPosterior(alpha=16.0, beta=2.0),
                          discount=0.9, floor_alpha=0.3,
                          floor_C_spec_usd=1.0, floor_L_value_usd=1.0)
    ctl = RolloutController(
        svc, RolloutConfig(cooldown_ticks=4, probe_budget=4,
                           min_obs=(3, 3, 3)))
    inj = [FaultInjector(FaultPlan(
        trace=DriftTrace.flip(15 + 5 * r, rate1=0.02, revert_at=45 + 5 * r),
        seed=SEED + r)) for r in range(4)]

    def one_tick(i: int) -> None:
        ctl.tick(list(range(4)), alpha=0.5, lambda_usd_per_s=0.9,
                 latency_s=3.0, input_tokens=500, output_tokens=300,
                 input_price=3e-6, output_price=15e-6,
                 outcomes=[(r, inj[r].outcome()) for r in range(4)])

    for i in range(5):                    # warm both executables
        one_tick(i)
    warm = online_mod._tick._cache_size()
    for i in range(5, ticks):
        one_tick(i)
    end = online_mod._tick._cache_size()
    if end != warm:
        raise AssertionError(
            f"phase churn recompiled: cache {warm} -> {end}")
    kinds = {t["kind"] for t in ctl.transitions}
    if not {"rollout_promote", "rollout_demote"} <= kinds:
        raise AssertionError(
            f"churn run failed to exercise the lifecycle: {kinds}")
    return {"asserted": True, "churn_ticks": ticks,
            "tick_executables": warm, "transition_kinds": sorted(kinds)}


# --------------------------------------------------------------------------
# gate 4: the acceptance flip, end to end
# --------------------------------------------------------------------------
def acceptance_flip() -> dict:
    """The issue's acceptance scenario through the full stack: flip at a
    known tick -> demote inside the trigger window, billed in USD ->
    revert -> cooldown + probes -> re-promoted to FULL."""
    from repro.serving.scenarios import adversarial_scenarios, run_scenario

    sc = adversarial_scenarios(SEED)[0]          # sudden_flip
    flip_at = sc.traces[0].at
    revert_at = sc.traces[0].until
    res = run_scenario(sc)
    if not res.demote_ticks:
        raise AssertionError("flip scenario never demoted")
    first_demote = res.demote_ticks[0]
    if not (flip_at <= first_demote <= flip_at + TRIGGER_WINDOW_TICKS):
        raise AssertionError(
            f"demote at tick {first_demote} outside "
            f"[{flip_at}, {flip_at + TRIGGER_WINDOW_TICKS}]")
    usd = res.usd_attribution.get("tenant0|rollout_demote", 0.0)
    if usd <= 0.0:
        raise AssertionError("demotion carried no USD attribution")
    if res.final_phases != ["FULL"]:
        raise AssertionError(
            f"row did not re-promote after revert: {res.final_phases}")
    re_promotes = [t for t in res.promote_ticks if t > revert_at]
    if len(re_promotes) < 3:
        raise AssertionError(
            f"expected the full re-promotion ladder after revert, "
            f"got promotes at {res.promote_ticks}")
    if res.events.get("rollout_reenter", 0) < 1:
        raise AssertionError("recovery skipped the cooldown re-entry probe")
    if res.events.get("drift_trip", 0) < 1:
        raise AssertionError("frontend never folded the breach into a trip")
    return {
        "flip_at": flip_at, "revert_at": revert_at,
        "first_demote_tick": first_demote,
        "trigger_window_ticks": TRIGGER_WINDOW_TICKS,
        "demote_usd": round(usd, 6),
        "re_promote_ticks": re_promotes,
        "final_phase": res.final_phases[0],
        "events": res.events,
    }


# --------------------------------------------------------------------------
# the Pareto table
# --------------------------------------------------------------------------
def pareto_table(ticks: int = 90) -> list[dict]:
    """One row per production archetype: dominant-mode probability in,
    lifecycle outcome out.  'Pareto' because the frontier is visible in
    the columns — speculate share bought vs. demotions paid."""
    from repro.core.archetypes import ARCHETYPES
    from repro.serving.scenarios import archetype_scenarios, run_scenario

    rows = []
    for sc in archetype_scenarios(SEED, ticks=ticks):
        res = run_scenario(sc)
        arch = ARCHETYPES[sc.archetype]
        rows.append({
            "archetype": sc.archetype,
            "p_mode": round(arch.profile().p_mode, 4),
            "speculate_rate": round(res.speculate_rate, 4),
            "success_rate": round(res.success_rate, 4),
            "final_phases": res.phase_counts(),
            "promotes": len(res.promote_ticks),
            "demotes": len(res.demote_ticks),
            "demote_usd": round(sum(
                v for k, v in res.usd_attribution.items()
                if k.endswith("|rollout_demote")), 6),
            "events": res.events,
        })
    rows.sort(key=lambda r: -r["p_mode"])
    return rows


def _assert_pareto_separates(rows: list[dict]) -> None:
    """The table must actually separate: the highest-p_mode archetype
    ends FULL with no demotions; the lowest never leaves SHADOW."""
    top, bottom = rows[0], rows[-1]
    if top["final_phases"] != {"FULL": 1} or top["demotes"] != 0:
        raise AssertionError(f"best-fit archetype did not run clean: {top}")
    if "FULL" in bottom["final_phases"] or bottom["promotes"] != 0:
        raise AssertionError(
            f"worst-fit archetype was promoted anyway: {bottom}")


# --------------------------------------------------------------------------
# the record
# --------------------------------------------------------------------------
def _record(*, timed: bool, pareto_ticks: int = 90) -> dict:
    determinism = assert_scenario_determinism()
    parity = assert_lifecycle_parity()
    zero_recompile = assert_zero_recompile()
    acceptance = acceptance_flip()
    pareto = pareto_table(ticks=pareto_ticks)
    _assert_pareto_separates(pareto)

    decisions_per_s = 0.0
    if timed:
        from repro.core.online import OnlineDecisionService
        from repro.core.posterior import BetaPosterior
        from repro.core.rollout import RolloutConfig, RolloutController

        svc = OnlineDecisionService(credible_consecutive_n=3)
        n = 64
        for r in range(n):
            svc.register_edge((f"a{r}", "b"), tenant=f"t{r % 8}",
                              posterior=BetaPosterior(alpha=16.0, beta=2.0),
                              discount=0.9, floor_alpha=0.3,
                              floor_C_spec_usd=1.0, floor_L_value_usd=1.0)
        ctl = RolloutController(svc, RolloutConfig())
        rows = list(range(n))
        outcomes = [(r, True) for r in rows]
        kw = dict(alpha=0.5, lambda_usd_per_s=0.9, latency_s=3.0,
                  input_tokens=500, output_tokens=300,
                  input_price=3e-6, output_price=15e-6, outcomes=outcomes)
        for _ in range(5):
            ctl.tick(rows, **kw)
        reps = 200
        t0 = time.perf_counter()
        for _ in range(reps):
            ctl.tick(rows, **kw)
        wall = time.perf_counter() - t0
        decisions_per_s = reps * n / wall

    return {
        "benchmark": "rollout_lifecycle_fleet",
        "seed": SEED,
        "decisions_per_s": round(decisions_per_s, 2),
        "determinism": determinism,
        "parity": parity,
        "zero_recompile": zero_recompile,
        "acceptance": acceptance,
        "pareto": pareto,
    }


def rollout_record(*, write: bool = True) -> dict:
    """Gates -> Pareto fleet -> timed rollout tick -> BENCH_rollout.json."""
    record = _record(timed=True)
    if write:
        BENCH_PATH.write_text(json.dumps(record, indent=2) + "\n")
    return record


def smoke() -> dict:
    """The --smoke gate: every behavioral gate at full strength (they are
    all deterministic virtual-tick runs — no wall-clock claims), a
    shortened Pareto fleet, ``decisions_per_s == 0.0``, nothing
    written."""
    return _record(timed=False, pareto_ticks=60)


def benchmarks() -> list[tuple[str, float, str]]:
    rec = rollout_record()
    acc = rec["acceptance"]
    us_per_decision = 1e6 / rec["decisions_per_s"]
    full = sum(1 for r in rec["pareto"]
               if r["final_phases"] == {"FULL": 1})
    return [(
        "rollout_lifecycle",
        us_per_decision,
        (f"{rec['decisions_per_s']:.0f} decisions/s under lifecycle | "
         f"demote {acc['first_demote_tick'] - acc['flip_at']} ticks "
         f"after flip (${acc['demote_usd']:.2f}) | "
         f"{full}/{len(rec['pareto'])} archetypes reach FULL"),
    )]


if __name__ == "__main__":
    print(json.dumps(rollout_record(), indent=2))
