"""Assemble EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

    PYTHONPATH=src:. python -m benchmarks.make_experiments
prints the markdown tables; the narrative sections live in EXPERIMENTS.md
directly.
"""
from __future__ import annotations

import json
from pathlib import Path

from .roofline import ARTIFACT_DIR, load_cells, terms_of

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_all() -> tuple[list[dict], list[dict]]:
    single, multi = [], []
    for p in sorted(ARTIFACT_DIR.glob("*.json")):
        if any(p.stem.endswith(s) for s in
               ("_scatter", "_triangular", "_noremat", "_nofsdp")):
            continue
        c = json.loads(p.read_text())
        (multi if "multipod" in p.name else single).append(c)
    return single, multi


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def dryrun_table() -> str:
    single, multi = load_all()
    mp = {(c["arch"], c["shape"]): c for c in multi}
    lines = [
        "| arch | shape | 16×16 compile | peak GB/chip | fits 16GB | "
        "2×16×16 compile | collective schedule (AG/AR/RS/A2A/CP) |",
        "|---|---|---|---|---|---|---|",
    ]
    for c in single:
        key = (c["arch"], c["shape"])
        m = mp.get(key)
        if c.get("skipped"):
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | "
                         f"SKIP: sub-quadratic required |")
            continue
        ma = c["memory_analysis"]
        cs = c.get("collective_schedule", {})
        sched = "/".join(str(cs.get(k, 0)) for k in (
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute"))
        mp_t = f"{m['timing']['compile_s']:.0f}s" if m and not m.get("skipped") else "—"
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['timing']['compile_s']:.0f}s | "
            f"{ma['peak_estimate_bytes']/2**30:.2f} | "
            f"{'✓' if ma['fits_16gb'] else '✗'} | {mp_t} | {sched} |")
    return "\n".join(lines)


def roofline_table() -> str:
    single, _ = load_all()
    lines = [
        "| arch | shape | compute | memory (analytic) | collective | "
        "dominant | bound | MODEL/HLO flops | HLO-bytes term (CPU pipeline) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in single:
        if c.get("skipped") or "roofline" not in c:
            continue
        t = terms_of(c)
        lines.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"{t['dominant'].replace('_s','')} | {fmt_s(t['bound_s'])} | "
            f"{t['useful_ratio']:.3f} | {fmt_s(t['memory_s_hlo_cpu'])} |")
    return "\n".join(lines)


def summary() -> str:
    single, multi = load_all()
    live_s = [c for c in single if not c.get("skipped")]
    live_m = [c for c in multi if not c.get("skipped")]
    fits = sum(c["memory_analysis"]["fits_16gb"] for c in live_s)
    return (f"single-pod cells compiled: {len(live_s)} "
            f"(+{len(single)-len(live_s)} long_500k skips); "
            f"multi-pod cells compiled: {len(live_m)}; "
            f"fits-16GB: {fits}/{len(live_s)}")


def main() -> None:
    print("## Dry-run table\n")
    print(dryrun_table())
    print("\n## Roofline table\n")
    print(roofline_table())
    print("\n## Summary\n")
    print(summary())


if __name__ == "__main__":
    main()
