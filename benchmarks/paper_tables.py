"""One benchmark per paper table/figure (§7.6, §10.1–10.3, App. A/B)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.decision import (
    DecisionInputs,
    critical_k,
    decision_threshold,
    evaluate,
    expected_value,
)
from repro.core.posterior import BetaPosterior
from repro.core.pricing import TwoRateTokenCost
from repro.core.streaming import fractional_waste
from repro.core.taxonomy import DependencyType

# §10.1 worked-example parameters
W_IN, W_OUT, W_IP, W_OP = 500, 1000, 3e-6, 15e-6
W_C = W_IN * W_IP + W_OUT * W_OP            # 0.0165
W_L = 5.0 * 0.01                            # 0.05
# AutoReply
A_C = 500 * 3e-6 + 800 * 15e-6
A_L = 0.8 * 0.08


def table_critical_k() -> dict:
    """§7.6 numerical table at AutoReply parameters."""
    rows = {}
    for k in (2, 3, 5, 10, 20):
        P = 1.0 / k
        ev = expected_value(P, A_L, A_C)
        rows[k] = {
            "P": P, "EV": ev,
            **{f"alpha_{a}": ("SPECULATE" if ev >= decision_threshold(a, A_C)
                              else "WAIT") for a in (0.0, 0.5, 1.0)},
        }
    return {"rows": rows,
            "k_crit": {a: critical_k(A_L, A_C, a) for a in (0.0, 0.5, 1.0)}}


def table_alpha_sensitivity() -> dict:
    """§10.1 sensitivity tables at P = 0.733 and P = 0.4."""
    out = {}
    for P in (0.733, 0.4):
        out[P] = {}
        for a in (0.0, 0.2, 0.5, 0.8, 1.0):
            res = evaluate(DecisionInputs(P, a, 0.01, 5.0, W_IN, W_OUT, W_IP, W_OP))
            out[P][a] = {"EV": res.EV_usd, "threshold": res.threshold_usd,
                         "decision": res.decision.value}
    return out


def table_two_phase() -> dict:
    """§10.2 planning -> runtime override walk-through."""
    plan = evaluate(DecisionInputs(0.733, 0.5, 0.01, 5.0, W_IN, W_OUT, W_IP, W_OP))
    post = BetaPosterior(alpha=4.4, beta=1.6)
    post.update(False).update(False)            # two failures between phases
    runtime = evaluate(DecisionInputs(post.mean, 0.5, 0.01, 5.0, W_IN, W_OUT, W_IP, W_OP))
    alpha_09 = evaluate(DecisionInputs(post.mean, 0.9, 0.01, 5.0, W_IN, W_OUT, W_IP, W_OP))
    alpha_01 = evaluate(DecisionInputs(post.mean, 0.1, 0.01, 5.0, W_IN, W_OUT, W_IP, W_OP))
    downgrade = evaluate(DecisionInputs(0.35, 0.1, 0.01, 5.0, W_IN, W_OUT, W_IP, W_OP))
    return {
        "plan": plan.decision.value,
        "posterior_after_failures": post.mean,          # 0.55
        "runtime_EV": runtime.EV_usd,                   # 0.0201
        "runtime": runtime.decision.value,              # SPECULATE (margin narrowed)
        "alpha_0.9": alpha_09.decision.value,
        "alpha_0.1_paper_says_wait": alpha_01.decision.value,  # SPECULATE (inconsistency #3)
        "alpha_0.1_p035_downgrade": downgrade.decision.value,  # WAIT
    }


def table_streaming_cancellation() -> dict:
    """§10.3: 300/1000 tokens generated before tier failure."""
    cm = TwoRateTokenCost(W_IP, W_OP)
    planned = cm.cost(W_IN, W_OUT)
    actual = fractional_waste(cm, W_IN, W_OUT, 300)
    post = BetaPosterior(alpha=4.4, beta=1.6)
    post.update(False)
    return {
        "C_spec_planned": planned,        # 0.0165
        "C_spec_actual": actual,          # 0.0060
        "saving": planned - actual,       # 0.0105 (64%)
        "saving_pct": 100 * (planned - actual) / planned,
        "posterior_after_failure": post.mean,  # 0.629
    }


def table_posterior_updates() -> dict:
    """App. A.4 and App. B update tables."""
    a4 = BetaPosterior.from_dependency_type(DependencyType.LIST_OUTPUT_VARIABLE_LENGTH)
    a4_means = [a4.mean]
    for o in (True, True, False, True):
        a4_means.append(a4.update(o).mean)
    a4.update_batch(5, 0)
    a4_means.append(a4.mean)

    b = BetaPosterior.from_dependency_type(DependencyType.ROUTER_K_WAY, k=3)
    b_means = [b.mean]
    for o in (True, False, True, False, True):
        b_means.append(b.update(o).mean)
    return {
        "a4_means": [round(m, 3) for m in a4_means],  # .70 .80 .85 .68 .733 .855
        "a4_data_weight": a4.data_weight(),           # ~0.82
        "b_means": [round(m, 3) for m in b_means],    # .333 .556 .417 .533 .444 .524
    }


def benchmarks() -> list[tuple[str, float, str]]:
    rows = []
    for name, fn, derive in [
        ("table_7_6_critical_k", table_critical_k,
         lambda o: f"k_crit(1.0)={o['k_crit'][1.0]:.2f}"),
        ("table_10_1_alpha_sensitivity", table_alpha_sensitivity,
         lambda o: f"flip@P=0.4:alpha0.5={o[0.4][0.5]['decision']}"),
        ("table_10_2_two_phase", table_two_phase,
         lambda o: f"downgrade={o['alpha_0.1_p035_downgrade']}"),
        ("table_10_3_streaming", table_streaming_cancellation,
         lambda o: f"saving_pct={o['saving_pct']:.0f}"),
        ("table_a4_b_posterior", table_posterior_updates,
         lambda o: f"a4_final={o['a4_means'][-1]}"),
    ]:
        t0 = time.perf_counter()
        out = fn()
        rows.append((name, (time.perf_counter() - t0) * 1e6, derive(out)))
    return rows
