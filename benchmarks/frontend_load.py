"""Open-loop load + fault-matrix benchmark for the serving front-end.

Closed-loop benchmarks (PR 5's ``ticks_per_s``) measure the decision
core; they cannot see queueing.  This module offers *open-loop* load — a
seeded Poisson arrival process submits requests on its own clock,
independent of completions, the way real traffic does — against
``repro.serving.frontend.ServingFrontend`` and reports what an operator
would ask of the layer:

* sustained decisions/sec at the offered rate,
* shed rate (bulkhead + admission),
* p50/p99 submit→resolve latency (the deadline batcher's window plus
  one jit'd tick),

with the repo's standing discipline applied first: **parity before
timing**.  Under ``enable_x64`` the healthy path's decisions must be
bitwise equal to scalar ``decision.evaluate`` over the pre-tick
posterior snapshot, and the degraded path (breaker forced open) must be
bitwise the same scalar rule — only then is anything timed (at the
serving default dtype).

The fault matrix then drives the same front-end through injected
exception bursts, a hung tick under a watchdog timeout, a tenant flood,
and a §12.5 success-rate flip, asserting the three resilience
invariants from the issue: the sequential path is never blocked (every
ticket resolves), every shed/trip/fallback emits a USD-attributed
resilience event, and fallback decisions match the scalar rule.

Everything is persisted to ``BENCH_frontend.json`` (``write=False`` —
the --smoke path — returns the record without touching the file).
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Optional

import numpy as np

BENCH_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_frontend.json"

SEED = 0
LAMBDA_USD_PER_S = 0.9
PRICE_IN, PRICE_OUT = 3e-6, 15e-6


# --------------------------------------------------------------------------
# arrival process + request mix
# --------------------------------------------------------------------------
def poisson_arrivals(rate_hz: float, duration_s: float,
                     seed: int = SEED) -> np.ndarray:
    """Seeded open-loop arrival times in [0, duration): exponential
    inter-arrival gaps at ``rate_hz`` (the memoryless process a
    closed-loop driver cannot emulate — see EXPERIMENTS.md §Resilience)."""
    rng = np.random.default_rng(seed)
    n = max(16, int(rate_hz * duration_s * 2) + 64)
    t = np.cumsum(rng.exponential(1.0 / rate_hz, size=n))
    out = t[t < duration_s]
    if out.size == 0:
        raise ValueError("empty arrival trace; raise rate or duration")
    return out


def build_service(n_tenants: int = 4, edges_per_tenant: int = 4, *,
                  credible_consecutive_n: int = 5, seed: int = SEED):
    """A small multi-tenant registry with mixed priors and a credible
    floor on every row (so the §12.5 kill-switch can actually breach)."""
    from repro.core.online import OnlineDecisionService
    from repro.core.posterior import BetaPosterior

    rng = np.random.default_rng(seed)
    svc = OnlineDecisionService(
        credible_consecutive_n=credible_consecutive_n)
    for t in range(n_tenants):
        for e in range(edges_per_tenant):
            # priors with mean >= ~0.7 keep the credible bound comfortably
            # above the 0.35 floor under healthy traffic; only a §12.5
            # success-rate flip can walk it through the floor
            svc.register_edge(
                (f"agent{e}", f"agent{e + 1}"), tenant=f"tenant{t}",
                posterior=BetaPosterior(
                    alpha=float(rng.uniform(8.0, 24.0)),
                    beta=float(rng.uniform(1.0, 4.0))),
                floor_alpha=0.3, floor_C_spec_usd=1.0,
                floor_L_value_usd=1.0,   # floor = 0.7 * 1 / 2 = 0.35
            )
    return svc


def request_stream(svc, seed: int = SEED) -> Callable[[int], object]:
    """Deterministic request factory cycling the registry's rows with
    jittered D4 inputs."""
    from repro.serving.frontend import DecisionRequest

    rng = np.random.default_rng(seed + 1)
    n = svc.n_rows
    lat = rng.uniform(0.5, 5.0, size=4096)
    otok = rng.integers(64, 512, size=4096)

    def make(i: int) -> DecisionRequest:
        row = i % n
        tenant, edge = svc.row_key(row)
        j = i % 4096
        return DecisionRequest(
            row=row, tenant=tenant, edge=edge, alpha=0.5,
            lambda_usd_per_s=LAMBDA_USD_PER_S, latency_s=float(lat[j]),
            input_tokens=500.0, output_tokens=float(otok[j]),
            input_price=PRICE_IN, output_price=PRICE_OUT)

    return make


# --------------------------------------------------------------------------
# drivers
# --------------------------------------------------------------------------
class VirtualClock:
    """Injectable monotonic stand-in: tests/smoke advance it by hand."""

    def __init__(self, t: float = 0.0) -> None:
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


def _prefix_settler(tickets: list, settle: Optional[Callable[[], bool]]):
    """Settle resolved tickets in submission order (batches are FIFO, so
    ``done()`` flips in prefix order); launched speculations settle as
    soon as their answer lands, releasing the bulkhead slot the way a
    live executor would."""
    cursor = [0]

    def run() -> None:
        while cursor[0] < len(tickets) and tickets[cursor[0]].done():
            tk = tickets[cursor[0]]
            cursor[0] += 1
            if tk.result(0).speculate:
                tk.settle(settle() if settle is not None else True)

    return run


def drive_virtual(frontend, clock: VirtualClock, arrivals: np.ndarray,
                  make_request, *, settle: Optional[Callable[[], bool]]
                  = None) -> list:
    """Deterministic replay of the batcher loop on the virtual clock:
    submissions land at their arrival times and a tick fires exactly at
    batch-full or deadline, whichever first — the same policy
    ``ServingFrontend._loop`` runs on the wall clock.  Requires
    ``autostart=False``.  Returns the resolved tickets."""
    deadline = frontend.config.deadline_s
    tickets: list = []
    settle_done = _prefix_settler(tickets, settle)

    def fire_due(now: float) -> None:
        while True:
            t0 = frontend.oldest_pending_t
            if t0 is None or t0 + deadline > now:
                return
            clock.t = t0 + deadline
            frontend.pump()
            settle_done()

    for i, ta in enumerate(arrivals):
        fire_due(float(ta))
        clock.t = float(ta)
        tickets.append(frontend.submit(make_request(i)))
        if frontend.pending_count >= frontend.config.max_batch:
            frontend.pump()
        settle_done()
    # drain the tail
    while frontend.pending_count:
        fire_due(clock.t + deadline + 1.0)
    settle_done()
    for tk in tickets:
        tk.result(0)                  # all resolved — never blocks
    return tickets


def drive_open_loop(frontend, arrivals: np.ndarray, make_request, *,
                    settle: Optional[Callable[[], bool]] = None,
                    result_timeout_s: float = 10.0) -> tuple[list, float]:
    """Real-time open-loop run against the live batcher thread: submit at
    the trace's arrival times regardless of completions (settling
    resolved tickets opportunistically between submissions), then resolve
    and settle the stragglers.  Returns (tickets, wall_s)."""
    tickets: list = []
    settle_done = _prefix_settler(tickets, settle)
    t0 = time.perf_counter()
    for i, ta in enumerate(arrivals):
        lag = float(ta) - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        tickets.append(frontend.submit(make_request(i)))
        settle_done()
    for tk in tickets:
        tk.result(result_timeout_s)
    settle_done()
    return tickets, time.perf_counter() - t0


# --------------------------------------------------------------------------
# parity gates (run before any timing — repo discipline)
# --------------------------------------------------------------------------
def assert_frontend_parity(n_requests: int = 32) -> dict:
    """Bitwise-f64 gates on both chain stages.

    healthy: a pumped batch's per-request floats equal scalar
    ``decision.evaluate`` over the pre-tick posterior snapshot.
    degraded: with every circuit forced open, answers come from the
    scalar stage and equal ``decision.evaluate`` over the mirror —
    by construction *and* re-checked value-by-value here.
    """
    from jax.experimental import enable_x64

    from repro.core.decision import Decision, DecisionInputs, evaluate
    from repro.core.posterior import BetaPosterior
    from repro.serving.frontend import FrontendConfig, ServingFrontend

    with enable_x64():
        svc = build_service()
        make = request_stream(svc)
        fe = ServingFrontend(svc, FrontendConfig(max_batch=n_requests),
                             autostart=False)
        snap = svc.posterior_snapshot()
        reqs = [make(i) for i in range(n_requests)]
        tickets = [fe.submit(r) for r in reqs]
        fe.pump()

        def scalar_ref(r):
            post = BetaPosterior(alpha=float(snap[r.row, 0]),
                                 beta=float(snap[r.row, 1]))
            return evaluate(DecisionInputs(
                P=post.mean, alpha=r.alpha,
                lambda_usd_per_s=r.lambda_usd_per_s,
                latency_seconds=r.latency_s, input_tokens=r.input_tokens,
                output_tokens=r.output_tokens, input_price=r.input_price,
                output_price=r.output_price))

        for tk, r in zip(tickets, reqs):
            res, ref = tk.result(0), scalar_ref(r)
            if res.source != "service":
                raise AssertionError("healthy parity batch left the service path")
            same = (res.decision is ref.decision
                    and res.EV_usd == ref.EV_usd
                    and res.threshold_usd == ref.threshold_usd
                    and res.C_spec_usd == ref.C_spec_usd
                    and res.L_value_usd == ref.L_value_usd
                    and res.P_used == ref.P_used)
            if not same:
                raise AssertionError(
                    f"service tick != scalar evaluate on row {r.row}: "
                    f"{res} vs {ref}")
            if res.speculate:
                tk.release()

        # degraded stage: force every circuit open; submissions now answer
        # synchronously through the scalar fallback over the mirror
        for r in reqs:
            fe.breaker.trip(r.key)
        fb = [fe.submit(r) for r in reqs]
        for tk, r in zip(fb, reqs):
            res, ref = tk.result(0), scalar_ref(r)
            if res.source != "scalar":
                raise AssertionError("breaker-open request escaped the fallback stage")
            if not (res.decision is ref.decision and res.EV_usd == ref.EV_usd
                    and res.threshold_usd == ref.threshold_usd
                    and res.P_used == ref.P_used):
                raise AssertionError(
                    f"scalar fallback != decision.evaluate on row {r.row}")
            if res.speculate:
                tk.release()
        n_spec = sum(
            1 for tk in fb if tk.result(0).decision is Decision.SPECULATE)
    return {
        "service_vs_scalar_bitwise_f64": True,
        "fallback_vs_scalar_bitwise_f64": True,
        "requests": n_requests,
        "fallback_speculates": n_spec,
    }


# --------------------------------------------------------------------------
# fault matrix
# --------------------------------------------------------------------------
def _events_cover(frontend, *kinds: str) -> None:
    got = frontend.resilience.by_kind()
    missing = [k for k in kinds if got.get(k, 0) < 1]
    if missing:
        raise AssertionError(f"fault run emitted no {missing}; got {got}")


def _all_resolved(tickets) -> None:
    unresolved = sum(0 if t.done() else 1 for t in tickets)
    if unresolved:
        raise AssertionError(
            f"{unresolved} tickets unresolved — sequential path blocked")


def fault_matrix(seed: int = SEED) -> dict:
    """Deterministic degraded-mode scenarios; each returns its event
    counts and the USD attribution so the record shows what degradation
    cost.  Invariants asserted per scenario: every ticket resolves and
    every degradation leaves a resilience event."""
    from repro.serving.faults import FaultInjector, FaultPlan, FaultyService
    from repro.serving.frontend import FrontendConfig, ServingFrontend

    out: dict[str, dict] = {}

    # -- 1. exception burst: breaker opens, scalar fallback answers,
    # cooldown elapses, probe closes the circuit
    svc = build_service(n_tenants=1, edges_per_tenant=2, seed=seed)
    make = request_stream(svc, seed)
    inj = FaultInjector(FaultPlan(raise_from=0, raise_until=2, seed=seed))
    clock = VirtualClock()
    fe = ServingFrontend(
        FaultyService(svc, inj),
        FrontendConfig(max_batch=4, breaker_failure_threshold=2,
                       breaker_cooldown_s=0.25, bulkhead_limit=64),
        clock=clock, autostart=False)
    tickets = []
    for burst in range(4):                  # 2 faulted ticks, then healthy
        batch = [fe.submit(make(i)) for i in range(4 * burst, 4 * burst + 4)]
        tickets += batch
        fe.pump()
        for tk in batch:
            if tk.result(0).speculate:
                tk.settle(True)
        clock.advance(0.3)                  # past cooldown between bursts
    _all_resolved(tickets)
    _events_cover(fe, "exception", "breaker_open", "fallback_scalar",
                  "breaker_half_open", "breaker_close")
    out["exception_burst"] = {
        "events": fe.resilience.by_kind(), "stats": dict(fe.stats)}

    # -- 2. hung tick under the watchdog: SpeculationTimeout degrades the
    # batch to the scalar stage (real clock — the timeout is wall time)
    svc = build_service(n_tenants=1, edges_per_tenant=2, seed=seed)
    make = request_stream(svc, seed)
    inj = FaultInjector(FaultPlan(hang_calls=frozenset({0}), hang_s=0.3,
                                  seed=seed))
    fe = ServingFrontend(
        FaultyService(svc, inj),
        FrontendConfig(max_batch=4, tick_timeout_s=0.05, bulkhead_limit=64),
        autostart=False)
    tickets = [fe.submit(make(i)) for i in range(4)]
    t0 = time.perf_counter()
    fe.pump()
    blocked_s = time.perf_counter() - t0
    _all_resolved(tickets)
    for tk in tickets:
        if tk.result(0).source != "scalar":
            raise AssertionError("timed-out tick did not degrade to scalar")
        if tk.result(0).speculate:
            tk.release()
    _events_cover(fe, "timeout", "fallback_scalar")
    if blocked_s > 0.25:                    # watchdog, not the 0.3 s hang
        raise AssertionError(f"timeout path blocked {blocked_s:.3f}s")
    out["hung_tick"] = {
        "events": fe.resilience.by_kind(), "blocked_s": round(blocked_s, 4)}

    # -- 3. tenant flood: one tenant saturates its bulkhead and is shed;
    # the quiet tenant's requests all pass admission
    svc = build_service(n_tenants=2, edges_per_tenant=2, seed=seed)
    make = request_stream(svc, seed)
    fe = ServingFrontend(svc, FrontendConfig(max_batch=64, bulkhead_limit=4),
                         autostart=False)
    noisy = [make(i) for i in range(64) if make(i).tenant == "tenant0"]
    quiet = [make(i) for i in range(64) if make(i).tenant == "tenant1"][:4]
    flood = [fe.submit(r) for r in noisy]
    calm = [fe.submit(r) for r in quiet]
    fe.pump()
    _all_resolved(flood + calm)
    shed = [t for t in flood if t.result(0).source == "shed"]
    if len(shed) != len(noisy) - fe.config.bulkhead_limit:
        raise AssertionError(
            f"expected {len(noisy) - 4} sheds, got {len(shed)}")
    if any(t.result(0).source == "shed" for t in calm):
        raise AssertionError("quiet tenant shed during the flood")
    for t in flood + calm:
        if t.result(0).speculate:
            t.settle(True)
    _events_cover(fe, "shed")
    attrib = {f"{t}|{k}": round(v, 6)
              for (t, k), v in fe.resilience.usd_attribution().items()}
    if not any(k.startswith("tenant0|shed") and v > 0
               for k, v in attrib.items()):
        raise AssertionError("sheds carried no USD attribution")
    out["tenant_flood"] = {
        "events": fe.resilience.by_kind(), "usd_attribution": attrib}

    # -- 4. §12.5 success-rate flip: the drifting outcome stream drives
    # the credible bound through the row's floor; the in-graph
    # kill-switch breach folds into the breaker as a trip
    svc = build_service(n_tenants=1, edges_per_tenant=1,
                        credible_consecutive_n=2, seed=seed)
    make = request_stream(svc, seed)
    inj = FaultInjector(FaultPlan(success_rate0=0.95, success_rate1=0.02,
                                  drift_at=0, seed=seed))
    fe = ServingFrontend(svc, FrontendConfig(max_batch=2, bulkhead_limit=256,
                                             check_drift=True),
                         autostart=False)
    tickets = []
    for i in range(120):
        tk = fe.submit(make(0))
        tickets.append(tk)
        fe.pump()
        res = tk.result(0)
        if res.speculate:
            tk.settle(inj.outcome())       # post-flip failures pile on
        if fe.resilience.by_kind().get("drift_trip", 0):
            break
    _all_resolved(tickets)
    _events_cover(fe, "drift_trip", "breaker_open")
    # after the trip the breaker answers without the service
    post = fe.submit(make(0))
    if post.result(0).source not in ("scalar", "conservative"):
        raise AssertionError("tripped edge still reached the service")
    if post.result(0).speculate:
        post.release()
    out["drift_flip"] = {
        "events": fe.resilience.by_kind(),
        "ticks_to_trip": fe.ticks,
        "post_trip_source": post.result(0).source,
    }
    return out


# --------------------------------------------------------------------------
# the record
# --------------------------------------------------------------------------
def frontend_record(*, rate_hz: float = 800.0, duration_s: float = 2.5,
                    max_batch: int = 64, deadline_s: float = 0.005,
                    bulkhead_limit: int = 24, seed: int = SEED,
                    write: bool = True) -> dict:
    """Parity gates → fault matrix → timed open-loop run →
    BENCH_frontend.json."""
    from repro.serving.frontend import FrontendConfig, ServingFrontend

    parity = assert_frontend_parity()
    faults = fault_matrix(seed)

    svc = build_service(seed=seed)
    make = request_stream(svc, seed)
    arrivals = poisson_arrivals(rate_hz, duration_s, seed)
    cfg = FrontendConfig(max_batch=max_batch, deadline_s=deadline_s,
                         bulkhead_limit=bulkhead_limit)
    with ServingFrontend(svc, cfg) as fe:
        # warm both tick executables off the clock (the frontend pads
        # every batch to max_batch, so there are exactly two: settle-free
        # and with the packed outcome block) — round 1 compiles the
        # former, its settles make round 2 compile the latter
        for _ in range(2):
            warm = [fe.submit(make(i)) for i in range(max_batch)]
            for tk in warm:
                if tk.result(10.0).speculate:
                    tk.settle(True)
        rng = np.random.default_rng(seed + 2)
        settle = lambda: bool(rng.random() < 0.9)         # noqa: E731
        tickets, wall_s = drive_open_loop(fe, arrivals, make, settle=settle)
        lat = np.array([t.latency_s for t in tickets])
        stats = dict(fe.stats)
        events = fe.resilience.by_kind()
        attrib = {f"{t}|{k}": round(v, 6)
                  for (t, k), v in fe.resilience.usd_attribution().items()}
        ticks = fe.ticks

    n = len(tickets)
    shed = sum(1 for t in tickets if t.result(0).source == "shed")
    record = {
        "benchmark": "serving_frontend_open_loop",
        "seed": seed,
        "offered_rate_hz": rate_hz,
        "duration_s": duration_s,
        "requests": n,
        "config": {"max_batch": max_batch, "deadline_s": deadline_s,
                   "bulkhead_limit": bulkhead_limit},
        "decisions_per_s": round(n / wall_s, 2),
        "shed_rate": round(shed / n, 6),
        "latency_ms": {
            "p50": round(float(np.percentile(lat, 50)) * 1e3, 3),
            "p99": round(float(np.percentile(lat, 99)) * 1e3, 3),
            "max": round(float(lat.max()) * 1e3, 3),
        },
        "ticks": ticks,
        "deadline_ticks": stats["deadline_ticks"],
        "full_ticks": stats["full_ticks"],
        "stats": stats,
        "parity": parity,
        "fault_matrix": faults,
        "resilience_events": events,
        "usd_attribution": attrib,
    }
    if write:
        BENCH_PATH.write_text(json.dumps(record, indent=2) + "\n")
    return record


def smoke() -> dict:
    """The --smoke gate: both parity checks, the full fault matrix, and a
    deterministic virtual-clock open-loop trace (seeded Poisson arrivals,
    no wall-clock timing, nothing written).  The record keeps the full
    BENCH_frontend.json shape so schema drift breaks tier-1."""
    from repro.serving.frontend import FrontendConfig, ServingFrontend

    parity = assert_frontend_parity(n_requests=8)
    faults = fault_matrix(SEED)

    svc = build_service(n_tenants=2, edges_per_tenant=2)
    make = request_stream(svc)
    clock = VirtualClock()
    cfg = FrontendConfig(max_batch=8, deadline_s=0.002, bulkhead_limit=16)
    fe = ServingFrontend(svc, cfg, clock=clock, autostart=False)
    arrivals = poisson_arrivals(rate_hz=400.0, duration_s=0.25, seed=SEED)
    tickets = drive_virtual(fe, clock, arrivals, make)
    lat = np.array([t.latency_s for t in tickets])
    if lat.max() > cfg.deadline_s + 1e-9:
        raise AssertionError(
            "virtual-clock latency exceeded the deadline window")
    if fe.stats["deadline_ticks"] < 1:
        raise AssertionError("no deadline tick fired on a partial batch")
    n = len(tickets)
    shed = sum(1 for t in tickets if t.result(0).source == "shed")
    return {
        "benchmark": "serving_frontend_open_loop",
        "seed": SEED,
        "offered_rate_hz": 400.0,
        "duration_s": 0.25,
        "requests": n,
        "config": {"max_batch": cfg.max_batch, "deadline_s": cfg.deadline_s,
                   "bulkhead_limit": cfg.bulkhead_limit},
        "decisions_per_s": 0.0,            # no timing claims in smoke
        "shed_rate": round(shed / n, 6),
        "latency_ms": {
            "p50": round(float(np.percentile(lat, 50)) * 1e3, 3),
            "p99": round(float(np.percentile(lat, 99)) * 1e3, 3),
            "max": round(float(lat.max()) * 1e3, 3),
        },
        "ticks": fe.ticks,
        "deadline_ticks": fe.stats["deadline_ticks"],
        "full_ticks": fe.stats["full_ticks"],
        "stats": dict(fe.stats),
        "parity": parity,
        "fault_matrix": faults,
        "resilience_events": fe.resilience.by_kind(),
        "usd_attribution": {
            f"{t}|{k}": round(v, 6)
            for (t, k), v in fe.resilience.usd_attribution().items()},
    }


def benchmarks() -> list[tuple[str, float, str]]:
    rec = frontend_record()
    lat = rec["latency_ms"]
    us_per_decision = 1e6 / rec["decisions_per_s"]
    return [(
        "frontend_open_loop",
        us_per_decision,
        (f"sustained {rec['decisions_per_s']:.0f}/s at offered "
         f"{rec['offered_rate_hz']:.0f}/s | shed {rec['shed_rate']:.3f} | "
         f"p50 {lat['p50']}ms p99 {lat['p99']}ms | "
         f"ticks {rec['ticks']} ({rec['deadline_ticks']} deadline)"),
    )]


if __name__ == "__main__":
    print(json.dumps(frontend_record(), indent=2))
