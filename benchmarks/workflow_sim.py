"""End-to-end workflow simulation: the AutoReply scenario through the full
planner + executor, sweeping alpha (§12.3 canary sweep, simulated).

200 deterministic episodes per alpha: the upstream classifier emits an
intent from a Zipf-ish 5-way distribution with p_mode = 0.62 (§7.6's
running example); the downstream drafter is speculated with the modal
prediction.  Output: per-alpha mean latency / cost / waste — the
(latency, cost) Pareto the canary stage consumes — plus the sequential
control arm.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    DependencyType,
    Edge,
    ExecutorConfig,
    Operation,
    PlannerParams,
    Workflow,
    execute,
    plan_workflow,
)
from repro.core.posterior import BetaPosterior
from repro.core.predictor import HistoricalModalPredictor

INTENTS = ["billing", "support", "sales", "spam", "other"]
PROBS = [0.62, 0.12, 0.10, 0.09, 0.07]


def build_workflow(intent: str) -> Workflow:
    wf = Workflow("autoreply")
    wf.add_op(Operation(
        "classifier", run=lambda x: intent, latency_est_s=0.8,
        input_tokens_est=200, output_tokens_est=10,
        metadata={"input": "email", "chunks": 8},
    ))
    wf.add_op(Operation(
        "drafter", run=lambda i: f"draft[{i}]", latency_est_s=0.8,
        input_tokens_est=500, output_tokens_est=800,
    ))
    wf.add_edge(Edge("classifier", "drafter",
                     dep_type=DependencyType.ROUTER_K_WAY, k=5))
    return wf.freeze()


def sweep(alphas=(0.0, 0.1, 0.3, 0.5, 0.7, 0.9, 1.0), episodes: int = 200,
          seed: int = 20260531) -> dict:
    rng = np.random.default_rng(seed)
    draws = rng.choice(len(INTENTS), size=episodes, p=PROBS)
    results = {}
    for alpha in alphas:
        post = BetaPosterior.from_dependency_type(DependencyType.ROUTER_K_WAY, k=5)
        lat, cost, waste, committed, launched = [], [], [], 0, 0
        for e in range(episodes):
            intent = INTENTS[draws[e]]
            wf = build_workflow(intent)
            params = PlannerParams(
                alpha=alpha, lambda_usd_per_s=0.08,
                posteriors={("classifier", "drafter"): post},
            )
            plan, _ = plan_workflow(wf, params)
            pred = HistoricalModalPredictor()
            pred.observe("email", "billing")   # modal prediction
            cfg = ExecutorConfig(params=params,
                                 predictors={("classifier", "drafter"): pred})
            rep = execute(wf, plan, cfg)
            lat.append(rep.makespan_s)
            cost.append(rep.total_cost_usd)
            waste.append(rep.waste_usd)
            launched += sum(o.launched for o in rep.outcomes)
            committed += sum(o.committed for o in rep.outcomes)
        results[alpha] = {
            "latency_s": float(np.mean(lat)),
            "cost_usd": float(np.mean(cost)),
            "waste_usd": float(np.mean(waste)),
            "launched": launched,
            "committed": committed,
            "posterior_final": post.mean,
        }
    # sequential control arm
    wf = build_workflow("billing")
    results["control"] = {
        "latency_s": wf.sequential_latency(),
        "cost_usd": sum(
            op.input_tokens_est * 3e-6 + op.output_tokens_est * 15e-6
            for op in wf.ops.values()
        ),
        "waste_usd": 0.0,
    }
    return results


def benchmarks() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    res = sweep()
    dt = (time.perf_counter() - t0) * 1e6 / 200
    ctrl = res["control"]
    best = res[0.9]
    return [(
        "workflow_alpha_sweep", dt,
        f"control={ctrl['latency_s']:.2f}s alpha0.9={best['latency_s']:.2f}s "
        f"waste=${best['waste_usd']:.4f} committed={best['committed']}/{best['launched']}",
    )]
