"""End-to-end workflow simulation: the AutoReply scenario through the full
planner + executor, sweeping alpha (§12.3 canary sweep, simulated).

Two implementations of the same sweep:

* ``sweep``        — paper-faithful scalar path: one discrete-event
  ``execute`` call per episode (200 deterministic episodes per alpha; the
  upstream classifier emits an intent from a Zipf-ish 5-way distribution
  with p_mode = 0.62, §7.6's running example).
* ``fleet_sweep``  — the vectorized replay engine (repro.core.fleet): all
  episodes x all alphas in one jit-compiled XLA call.

``benchmarks()`` runs both, asserts the Pareto statistics agree, and
persists the speedup record to BENCH_fleet.json (machine-readable perf
trajectory across PRs; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import subprocess
import sys
import textwrap
import time

import numpy as np

from repro.core import (
    DependencyType,
    Edge,
    ExecutorConfig,
    Operation,
    PlannerParams,
    Workflow,
    episode_sharded_replay,
    execute,
    fleet_replay,
    lower_workflow,
    multi_tenant_replay,
    plan_workflow,
    stack_tenants,
)
from repro.core.posterior import BetaPosterior
from repro.core.predictor import HistoricalModalPredictor

INTENTS = ["billing", "support", "sales", "spam", "other"]
PROBS = [0.62, 0.12, 0.10, 0.09, 0.07]
DEFAULT_ALPHAS = (0.0, 0.1, 0.3, 0.5, 0.7, 0.9, 1.0)
LAMBDA_USD_PER_S = 0.08
# The beam record's latency-critical tier.  At the classic 0.08 the k=5
# router's cold prior (mean 0.2) times the 0.62 top-candidate confidence
# keeps beam EV below every threshold — nothing ever launches and the
# width axis is dead.  At 0.25 the alpha knee survives (alpha=0 stays in
# the cold-start trap) while the §7.6 marginal rule admits the runner-up
# once the posterior warms past ~0.53 and the third candidate past ~0.63,
# so the published Pareto actually exercises the width axis.
BEAM_LAMBDA_USD_PER_S = 0.25
SEED = 20260531
BENCH_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_fleet.json"


def build_workflow(intent: str) -> Workflow:
    wf = Workflow("autoreply")
    wf.add_op(Operation(
        "classifier", run=lambda x: intent, latency_est_s=0.8,
        input_tokens_est=200, output_tokens_est=10,
        metadata={"input": "email", "chunks": 8},
    ))
    wf.add_op(Operation(
        "drafter", run=lambda i: f"draft[{i}]", latency_est_s=0.8,
        input_tokens_est=500, output_tokens_est=800,
    ))
    wf.add_edge(Edge("classifier", "drafter",
                     dep_type=DependencyType.ROUTER_K_WAY, k=5))
    return wf.freeze()


def _draws(episodes: int, seed: int = SEED) -> np.ndarray:
    return np.random.default_rng(seed).choice(
        len(INTENTS), size=episodes, p=PROBS
    )


def sweep(alphas=DEFAULT_ALPHAS, episodes: int = 200,
          seed: int = SEED, *, use_lower_bound: bool = False,
          gamma: float = 0.1) -> dict:
    """Paper-faithful scalar sweep: plan + execute per episode.

    ``use_lower_bound=True`` runs the §7.5 conservative variant: both the
    planner and the Phase-2 runtime gate on the one-sided (1-gamma) lower
    credible bound instead of the posterior mean."""
    draws = _draws(episodes, seed)
    results = {}
    for alpha in alphas:
        post = BetaPosterior.from_dependency_type(DependencyType.ROUTER_K_WAY, k=5)
        lat, cost, waste, committed, launched = [], [], [], 0, 0
        for e in range(episodes):
            intent = INTENTS[draws[e]]
            wf = build_workflow(intent)
            params = PlannerParams(
                alpha=alpha, lambda_usd_per_s=LAMBDA_USD_PER_S,
                posteriors={("classifier", "drafter"): post},
                use_lower_bound=use_lower_bound, gamma=gamma,
            )
            plan, _ = plan_workflow(wf, params)
            pred = HistoricalModalPredictor()
            pred.observe("email", "billing")   # modal prediction
            cfg = ExecutorConfig(params=params,
                                 predictors={("classifier", "drafter"): pred},
                                 use_lower_bound=use_lower_bound,
                                 gamma=gamma)
            rep = execute(wf, plan, cfg)
            lat.append(rep.makespan_s)
            cost.append(rep.total_cost_usd)
            waste.append(rep.waste_usd)
            launched += sum(o.launched for o in rep.outcomes)
            committed += sum(o.committed for o in rep.outcomes)
        results[alpha] = {
            "latency_s": float(np.mean(lat)),
            "cost_usd": float(np.mean(cost)),
            "waste_usd": float(np.mean(waste)),
            "launched": launched,
            "committed": committed,
            "posterior_final": post.mean,
        }
    # sequential control arm
    wf = build_workflow("billing")
    results["control"] = {
        "latency_s": wf.sequential_latency(),
        "cost_usd": sum(
            op.input_tokens_est * 3e-6 + op.output_tokens_est * 15e-6
            for op in wf.ops.values()
        ),
        "waste_usd": 0.0,
    }
    return results


def _autoreply_fleet(episodes: int, seed: int = SEED, *,
                     use_lower_bound: bool = False, gamma: float = 0.1,
                     beam_confidences: dict | None = None):
    """The AutoReply workflow lowered for the fleet engine plus its
    synthetic episode log: returns (lowered, success, drafter_index).
    Shared by the fleet sweep, the episode-sharded record, the beam-width
    record and the multi-device tests."""
    draws = _draws(episodes, seed)
    wf = build_workflow("billing")
    edge_key = ("classifier", "drafter")
    params = PlannerParams(
        alpha=0.5, lambda_usd_per_s=LAMBDA_USD_PER_S,
        posteriors={edge_key: BetaPosterior.from_dependency_type(
            DependencyType.ROUTER_K_WAY, k=5)},
        use_lower_bound=use_lower_bound, gamma=gamma,
    )
    pred = HistoricalModalPredictor()
    pred.observe("email", "billing")
    lowered = lower_workflow(wf, params, predictors={edge_key: pred},
                             beam_confidences=beam_confidences)
    vi = lowered.names.index("drafter")
    success = np.zeros((episodes, lowered.n_ops), bool)
    success[:, vi] = draws == 0        # modal prediction is "billing"
    return lowered, success, vi


def fleet_sweep(alphas=DEFAULT_ALPHAS, episodes: int = 200,
                seed: int = SEED, *, use_lower_bound: bool = False,
                gamma: float = 0.1) -> dict:
    """The same sweep through the vectorized fleet replay engine: one
    XLA call for all episodes x alphas.  ``use_lower_bound=True`` gates
    on the jax-native betaincinv credible bound inside that same call."""
    lowered, success, vi = _autoreply_fleet(
        episodes, seed, use_lower_bound=use_lower_bound, gamma=gamma)
    report = fleet_replay(lowered, success, np.asarray(alphas),
                          LAMBDA_USD_PER_S)
    results = {}
    for gi, alpha in enumerate(alphas):
        results[alpha] = {
            "latency_s": float(report.makespan_s[:, gi].mean()),
            "cost_usd": float(report.total_cost_usd[:, gi].mean()),
            "waste_usd": float(report.waste_usd[:, gi].mean()),
            "launched": int(report.launched[:, gi].sum()),
            "committed": int(report.committed[:, gi].sum()),
            "posterior_final": float(
                report.post_alpha[-1, gi, vi]
                / (report.post_alpha[-1, gi, vi] + report.post_beta[-1, gi, vi])
            ),
        }
    return results


def assert_pareto_parity(scalar: dict, fleet: dict, alphas=DEFAULT_ALPHAS,
                         rtol: float = 1e-4) -> dict:
    """The fleet path must reproduce the scalar AutoReply Pareto: identical
    launch/commit counts, matching latency/cost/waste means."""
    worst = 0.0
    for alpha in alphas:
        s, f = scalar[alpha], fleet[alpha]
        if s["launched"] != f["launched"] or s["committed"] != f["committed"]:
            raise AssertionError(
                f"fleet/scalar divergence at alpha={alpha}: "
                f"launched {s['launched']}!={f['launched']} or committed "
                f"{s['committed']}!={f['committed']}"
            )
        for key in ("latency_s", "cost_usd", "waste_usd"):
            denom = max(abs(s[key]), 1e-12)
            rel = abs(s[key] - f[key]) / denom
            worst = max(worst, rel)
            if rel > rtol:
                raise AssertionError(
                    f"fleet/scalar divergence at alpha={alpha} {key}: "
                    f"{s[key]} vs {f[key]} (rel {rel:.2e})"
                )
    return {"max_rel_error": worst}


def _mt_stack(tenants: int = 8, episodes: int = 200, seed: int = SEED):
    """Stack ``tenants`` AutoReply variants: each tenant carries its own
    taxonomy-keyed prior (k-way router fan-out varies per tenant), its own
    intent draw stream, and its own episode log — the multi-tenant §12.1
    deployment shape (one edge name, many tenants)."""
    wf = build_workflow("billing")
    edge_key = ("classifier", "drafter")
    lowereds, succs, names = [], [], []
    for t in range(tenants):
        k = 3 + (t % 6)              # per-tenant router fan-out -> prior
        params = PlannerParams(
            alpha=0.5, lambda_usd_per_s=LAMBDA_USD_PER_S,
            posteriors={edge_key: BetaPosterior.from_dependency_type(
                DependencyType.ROUTER_K_WAY, k=k)},
        )
        pred = HistoricalModalPredictor()
        pred.observe("email", "billing")
        lowered = lower_workflow(wf, params, predictors={edge_key: pred})
        vi = lowered.names.index("drafter")
        draws = _draws(episodes, seed + t)
        success = np.zeros((episodes, lowered.n_ops), bool)
        success[:, vi] = draws == 0
        lowereds.append(lowered)
        succs.append(success)
        names.append(f"tenant{t}")
    return stack_tenants(lowereds, succs, tenants=names)


_SCALING_BODY = """
    import os, sys, time, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    sys.path[:0] = {paths!r}
    import jax
    import numpy as np
    from benchmarks.workflow_sim import DEFAULT_ALPHAS, LAMBDA_USD_PER_S, _mt_stack
    from repro.core.fleet import multi_tenant_replay
    from repro.launch.mesh import make_fleet_mesh
    stack = _mt_stack(tenants={tenants}, episodes={episodes})
    alphas = np.asarray(DEFAULT_ALPHAS)
    mesh = make_fleet_mesh()
    multi_tenant_replay(stack, alphas, LAMBDA_USD_PER_S, mesh=mesh)  # warm-up
    t0 = time.perf_counter()
    rep = multi_tenant_replay(stack, alphas, LAMBDA_USD_PER_S, mesh=mesh)
    wall = time.perf_counter() - t0
    shards = len(rep.post_final.sharding.device_set)
    print(json.dumps({{"devices": len(jax.devices()), "shards": shards,
                       "wall_s": wall}}))
"""


def multi_tenant_scaling(devices=(1, 2, 4, 8), tenants: int = 8,
                         episodes: int = 200) -> list[dict]:
    """Time the sharded multi-tenant call under 1/2/4/8 forced host
    devices (fresh subprocess each — XLA_FLAGS must be set before the
    first jax import).  Wall-clock scaling on CPU is bounded by the
    physical core count (recorded as ``host_cpus``); the shard count
    verifies the tenants x grid axis really was partitioned."""
    root = str(pathlib.Path(__file__).resolve().parents[1])
    paths = [root, str(pathlib.Path(root) / "src")]
    rows = []
    for d in devices:
        code = textwrap.dedent(_SCALING_BODY.format(
            devices=d, paths=paths, tenants=tenants, episodes=episodes))
        proc = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=600, env={**os.environ, "PYTHONPATH": paths[1]},
        )
        if proc.returncode != 0:
            raise RuntimeError(
                f"scaling subprocess ({d} devices) failed:\n"
                f"{proc.stderr[-2000:]}")
        row = json.loads(proc.stdout.strip().splitlines()[-1])
        row["host_cpus"] = os.cpu_count()
        rows.append(row)
    return rows


def multi_tenant_record(tenants: int = 8, alphas=DEFAULT_ALPHAS,
                        episodes: int = 200, seed: int = SEED,
                        scaling_devices=(1, 2, 4, 8)) -> dict:
    """The BENCH_fleet.json ``multi_tenant`` section: ≥8 tenants x grid x
    episodes in one jit'd sharded call, bitwise (f64) per-tenant parity
    against T independent ``fleet_replay`` calls, one-call vs per-tenant
    wall times, and the forced-host-device scaling rows."""
    from jax.experimental import enable_x64

    alphas_arr = np.asarray(alphas)

    # --- parity first (f64, unsharded single device): every per-tenant
    # row block of the one-call report must equal its independent replay.
    # The single run replays the same padded lowering with the tenant's
    # episode mask, so the comparison stays bitwise even if the stack
    # ever goes ragged across episodes or op counts.
    with enable_x64():
        stack = _mt_stack(tenants, episodes, seed)
        report = multi_tenant_replay(stack, alphas_arr, LAMBDA_USD_PER_S)
        for t in range(tenants):
            single = fleet_replay(
                stack.lowered[t], stack.success[t], alphas_arr,
                LAMBDA_USD_PER_S, pred_ok=stack.pred_ok[t],
                ep_mask=stack.ep_mask[t])
            for f in dataclasses.fields(single):
                if f.name in ("alphas", "lambdas", "ep_mask"):
                    continue
                if not np.array_equal(getattr(single, f.name),
                                      getattr(report, f.name)[t]):
                    raise AssertionError(
                        f"multi-tenant parity broke: tenant {t} field "
                        f"{f.name}")

    # --- then speed (fleet default dtype, matching the other records)
    stack = _mt_stack(tenants, episodes, seed)
    multi_tenant_replay(stack, alphas_arr, LAMBDA_USD_PER_S)   # warm-up
    t0 = time.perf_counter()
    multi_tenant_replay(stack, alphas_arr, LAMBDA_USD_PER_S)
    one_call_s = time.perf_counter() - t0

    for t in range(tenants):                                   # warm-up
        fleet_replay(stack.lowered[t], stack.success[t], alphas_arr,
                     LAMBDA_USD_PER_S, pred_ok=stack.pred_ok[t])
    t0 = time.perf_counter()
    for t in range(tenants):
        fleet_replay(stack.lowered[t], stack.success[t], alphas_arr,
                     LAMBDA_USD_PER_S, pred_ok=stack.pred_ok[t])
    per_tenant_s = time.perf_counter() - t0

    record = {
        "benchmark": "autoreply_multi_tenant_replay",
        "tenants": tenants,
        "grid_points": len(alphas_arr),
        "episodes": episodes,
        "one_call_s": one_call_s,
        "per_tenant_calls_s": per_tenant_s,
        "speedup": per_tenant_s / one_call_s,
        "parity": {"bitwise_f64_vs_independent_fleet_replay": True},
        "scaling": multi_tenant_scaling(
            scaling_devices, tenants, episodes) if scaling_devices else [],
    }
    return record


def _episode_sharded_shards(lowered, success, alphas, mesh,
                            n_segments) -> int:
    """Count the devices the episode-sharded stats pass really
    partitioned over.  The public report is numpy, so the check reaches
    one level down: rebuild the executable's inputs and read the output
    sharding off the cached compiled call."""
    import jax.numpy as jnp

    from repro.core import fleet
    from repro.core.batch_decision import _f

    alphas = np.atleast_1d(np.asarray(alphas, float))
    lams = np.full_like(alphas, LAMBDA_USD_PER_S)
    chunks = fleet.chunk_episodes(lowered, success, n_segments)
    static = fleet._pack_static(lowered, chunks.has_refiner)
    post0 = jnp.broadcast_to(
        jnp.stack([_f(lowered.a0), _f(lowered.b0)], -1)[None],
        (alphas.shape[0], lowered.n_ops, 2))
    args = (_f(lowered.discount), _f(alphas), _f(lams), _f(lowered.gamma),
            jnp.asarray(chunks.success), jnp.asarray(chunks.pred_ok),
            _f(chunks.chunk_P), jnp.asarray(chunks.ep_mask))
    starts, _ = fleet._boundary_scan(static, post0, *args, throttle_every=1,
                                     K=1, use_lower_bound=False)
    fn = fleet._seg_executable(mesh, "fleet", 1, 1, False)
    _, ys = fn(static, starts, *args)
    return len(ys["makespan_s"].sharding.device_set)


_ES_SCALING_BODY = """
    import os, sys, time, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    sys.path[:0] = {paths!r}
    import jax
    import numpy as np
    from benchmarks.workflow_sim import (
        DEFAULT_ALPHAS, LAMBDA_USD_PER_S, _autoreply_fleet,
        _episode_sharded_shards)
    from repro.core import episode_sharded_replay
    from repro.launch.mesh import make_fleet_mesh
    lowered, success, _ = _autoreply_fleet(episodes={episodes})
    alphas = np.asarray(DEFAULT_ALPHAS)
    mesh = make_fleet_mesh()
    kw = dict(n_segments={segments}, mesh=mesh)
    episode_sharded_replay(lowered, success, alphas, LAMBDA_USD_PER_S, **kw)
    t0 = time.perf_counter()
    episode_sharded_replay(lowered, success, alphas, LAMBDA_USD_PER_S, **kw)
    wall = time.perf_counter() - t0
    shards = _episode_sharded_shards(lowered, success, alphas, mesh,
                                     {segments})
    print(json.dumps({{"devices": len(jax.devices()), "shards": shards,
                       "wall_s": wall}}))
    sys.stdout.flush()
    os._exit(0)  # skip XLA teardown: it can segfault under forced device
                 # counts with GB-scale live buffers, after the row above
                 # has already been emitted
"""


def episode_sharded_scaling(devices=(1, 2, 4, 8), episodes: int = 1_000_000,
                            segments: int = 8) -> list[dict]:
    """Time the segment-sharded single-tenant replay under 1/2/4/8 forced
    host devices (fresh subprocess each, as in
    :func:`multi_tenant_scaling`).  Same 2-core caveat: wall-clock past
    the physical core count is overhead-bound; the ``shards`` column is
    what verifies the episode axis really was partitioned."""
    root = str(pathlib.Path(__file__).resolve().parents[1])
    paths = [root, str(pathlib.Path(root) / "src")]
    rows = []
    for d in devices:
        code = textwrap.dedent(_ES_SCALING_BODY.format(
            devices=d, paths=paths, episodes=episodes, segments=segments))
        proc = subprocess.run(
            [sys.executable, "-c", code], capture_output=True, text=True,
            timeout=1200, env={**os.environ, "PYTHONPATH": paths[1]},
        )
        if proc.returncode != 0:
            raise RuntimeError(
                f"episode-sharded scaling subprocess ({d} devices) "
                f"failed:\n{proc.stderr[-2000:]}")
        row = json.loads(proc.stdout.strip().splitlines()[-1])
        row["host_cpus"] = os.cpu_count()
        rows.append(row)
    return rows


def episode_sharded_record(episodes: int = 1_000_000,
                           alphas=DEFAULT_ALPHAS, seed: int = SEED,
                           segments: int = 8,
                           scaling_devices=(1, 2, 4, 8)) -> dict:
    """The BENCH_fleet.json ``episode_sharded`` section: one tenant's
    E-episode AutoReply log replayed as C independent scan segments with
    the posterior-handoff boundary pass.  Bitwise-f64 parity against the
    unsharded ``fleet_replay`` is asserted at the full episode count
    *before* any timing is reported, as is the decision-fraction parity
    of the log-axis-sharded §12.1 counterfactual grid the calibration
    reroute rides on."""
    from jax.experimental import enable_x64

    from repro.core.batch_decision import (
        counterfactual_grid,
        counterfactual_grid_sharded,
    )

    alphas_arr = np.asarray(alphas)

    # --- parity first (f64, in-process): every field of the sharded
    # report must equal the sequential scan at the full episode count.
    with enable_x64():
        lowered, success, _ = _autoreply_fleet(episodes, seed)
        base = fleet_replay(lowered, success, alphas_arr, LAMBDA_USD_PER_S)
        sharded = episode_sharded_replay(
            lowered, success, alphas_arr, LAMBDA_USD_PER_S,
            n_segments=segments)
        for f in dataclasses.fields(base):
            if not np.array_equal(getattr(base, f.name),
                                  getattr(sharded, f.name)):
                raise AssertionError(
                    f"episode-sharded parity broke: field {f.name}")
        piped = episode_sharded_replay(
            lowered, success, alphas_arr, LAMBDA_USD_PER_S,
            n_segments=segments, pipelined=True)
        for f in dataclasses.fields(base):
            if not np.array_equal(getattr(base, f.name),
                                  getattr(piped, f.name)):
                raise AssertionError(
                    f"pipelined episode-sharded parity broke: field {f.name}")
        del base, sharded, piped

    # --- grid-reroute parity: the log-axis-sharded counterfactual grid
    # (what offline_replay uses past its shard_threshold) vs the
    # unsharded grid — decision fractions bitwise, float sums to reorder
    # tolerance.
    rng = np.random.default_rng(seed)
    n_rows = min(episodes, 4096)
    glat = rng.uniform(0.2, 3.0, n_rows)
    gcost = rng.uniform(0.001, 0.03, n_rows)
    g_alphas = np.array([0.0, 0.25, 0.5, 0.75, 1.0])
    g_lams = np.array([0.005, 0.01, 0.05, 0.1])
    with enable_x64():
        g0 = counterfactual_grid(0.62, glat, gcost, g_alphas, g_lams,
                                 rho=0.41)
        g1 = counterfactual_grid_sharded(0.62, glat, gcost, g_alphas,
                                         g_lams, rho=0.41,
                                         segments=max(2, segments))
    if not np.array_equal(g0["speculate_fraction"],
                          g1["speculate_fraction"]):
        raise AssertionError("sharded grid decision fractions diverged")
    grid_rel = max(
        float(np.max(np.abs(g0[k] - g1[k])
                     / np.maximum(np.abs(g0[k]), 1e-300)))
        for k in ("expected_latency_s", "expected_cost_usd",
                  "expected_waste_usd"))
    if grid_rel > 1e-12:
        raise AssertionError(
            f"sharded grid drifted past reorder tolerance: {grid_rel:.2e}")

    # --- then speed (fleet default dtype).  Even on one in-process
    # device the sharded path wins (~2x at 1M episodes): vmapping the
    # stats pass over C segments vectorizes the per-episode body across
    # the segment batch dim, cutting the sequential scan depth C-fold —
    # which more than repays the extra boundary pass.  The multi-device
    # story lives in the scaling rows (on this 2-core container in the
    # shards column rather than the wall-clock; EXPERIMENTS.md §Perf).
    lowered, success, _ = _autoreply_fleet(episodes, seed)
    fleet_replay(lowered, success, alphas_arr, LAMBDA_USD_PER_S)
    t0 = time.perf_counter()
    fleet_replay(lowered, success, alphas_arr, LAMBDA_USD_PER_S)
    unsharded_s = time.perf_counter() - t0

    episode_sharded_replay(lowered, success, alphas_arr, LAMBDA_USD_PER_S,
                           n_segments=segments)
    t0 = time.perf_counter()
    episode_sharded_replay(lowered, success, alphas_arr, LAMBDA_USD_PER_S,
                           n_segments=segments)
    sharded_s = time.perf_counter() - t0

    # Pipelined variant: same math, but segment c's stats dispatch
    # overlaps segment c+1's posterior handoff via JAX's async dispatch
    # (and skips the last segment's handoff outright).  The trade: stats
    # run one executable per segment instead of vmapped across segments,
    # so on this 2-core container (no devices to overlap onto) the row
    # records a *slower* wall than two-pass — kept as an honest baseline
    # for multi-device hosts, where per-segment stats land on their own
    # devices (parity was asserted above, pre-timing).
    episode_sharded_replay(lowered, success, alphas_arr, LAMBDA_USD_PER_S,
                           n_segments=segments, pipelined=True)
    t0 = time.perf_counter()
    episode_sharded_replay(lowered, success, alphas_arr, LAMBDA_USD_PER_S,
                           n_segments=segments, pipelined=True)
    pipelined_s = time.perf_counter() - t0

    return {
        "benchmark": "autoreply_episode_sharded_replay",
        "episodes": episodes,
        "segments": segments,
        "grid_points": len(alphas_arr),
        "unsharded_s": unsharded_s,
        "sharded_s": sharded_s,
        "speedup": unsharded_s / sharded_s,
        "parity": {
            "bitwise_f64_vs_fleet_replay": True,
            "grid_reroute_fraction_bitwise": True,
            "grid_reroute_max_rel_error": grid_rel,
        },
        "pipelined": {
            "pipelined_s": pipelined_s,
            "speedup_vs_two_pass": sharded_s / pipelined_s,
            "speedup_vs_unsharded": unsharded_s / pipelined_s,
            "parity": {"bitwise_f64_vs_fleet_replay": True},
        },
        "scaling": episode_sharded_scaling(
            scaling_devices, episodes, segments) if scaling_devices else [],
    }


def online_service_record(batch_sizes=(1, 64, 1024), n_rows: int = 64,
                          reps: int = 20, seed: int = SEED,
                          require_speedup: float | None = 20.0) -> dict:
    """The BENCH_fleet.json ``online_service`` section: the jit'd batched
    decision service (device-resident posterior table, one donated tick
    per batch) vs the scalar ``ThreadedSpeculativeRunner.decide`` loop.

    Parity is asserted before any timing: under ``enable_x64`` every
    batched decision (flag, EV, threshold, margin) must be bitwise equal
    to ``decision.evaluate`` on the same posterior rows — the
    contraction-pinned gate, not the fleet engine's 1-ULP FMA tolerance —
    and the §7.5 lower-bound tick must flag-match the scipy-backed scalar
    path.  Timing then runs at the fleet default dtype: per batch size B,
    ``reps`` warm ticks (each tick's flags pulled to host — the honest
    per-tick round-trip an online service pays) against B scalar
    ``decide`` calls per rep.  ``require_speedup`` (full runs) asserts
    the B=max per-decision speedup floor.
    """
    from jax.experimental import enable_x64

    from repro.core.decision import Decision
    from repro.core.online import OnlineDecisionService
    from repro.core.posterior import BetaPosterior
    from repro.serving.spec_bridge import EngineOp, ThreadedSpeculativeRunner

    rng = np.random.default_rng(seed)

    def build_service(**kw):
        svc = OnlineDecisionService(**kw)
        for i in range(n_rows):
            svc.register_edge(("classifier", f"drafter{i}"),
                              dep_type=DependencyType.ROUTER_K_WAY,
                              k=2 + i % 7)
        return svc

    op = EngineOp("drafter", engine=None, max_new_tokens=160)
    runner = ThreadedSpeculativeRunner(lambda: (None, None), op)
    pricing_in, pricing_out = 3e-6, 15e-6      # paper/frontier-default

    def requests(B):
        return dict(
            rows=rng.integers(0, n_rows, B),
            alpha=rng.uniform(0.0, 1.0, B),
            lam=rng.uniform(1e-3, 0.5, B),
            lat=rng.uniform(0.05, 4.0, B),
        )

    def svc_tick(svc, req, **kw):
        return svc.tick(
            req["rows"], alpha=req["alpha"], lambda_usd_per_s=req["lam"],
            latency_s=req["lat"], input_tokens=32, output_tokens=160,
            input_price=pricing_in, output_price=pricing_out, **kw)

    # --- parity first (f64): bitwise vs the scalar runner's evaluate
    with enable_x64():
        svc = build_service()
        B_par = max(batch_sizes)
        req = requests(B_par)
        snap = svc.posterior_snapshot()
        d = svc_tick(svc, req)
        for i in range(B_par):
            r = int(req["rows"][i])
            post = BetaPosterior(alpha=float(snap[r, 0]), beta=float(snap[r, 1]))
            ref = runner.decide_full(post, float(req["alpha"][i]),
                                     float(req["lam"][i]), float(req["lat"][i]))
            if (bool(d.flag[i]) != (ref.decision is Decision.SPECULATE)
                    or d.EV_usd[i] != ref.EV_usd
                    or d.threshold_usd[i] != ref.threshold_usd
                    or d.margin_usd[i] != ref.margin_usd):
                raise AssertionError(
                    f"online service / scalar decide divergence at row {i}")
        # §7.5 flag parity (EV inherits the betaincinv-vs-ppf allowance)
        d_lb = svc_tick(svc, req, use_lower_bound=True)
        for i in range(B_par):
            r = int(req["rows"][i])
            post = BetaPosterior(alpha=float(snap[r, 0]), beta=float(snap[r, 1]))
            ref = runner.decide_full(post, float(req["alpha"][i]),
                                     float(req["lam"][i]), float(req["lat"][i]),
                                     use_lower_bound=True)
            if bool(d_lb.flag[i]) != (ref.decision is Decision.SPECULATE):
                raise AssertionError(
                    f"online service lower-bound flag divergence at row {i}")

    # --- then speed (fleet default dtype).  This container's 2 cores are
    # shared with the harness, so each side takes the best of several
    # rounds — the standard noise-robust estimator; both sides get the
    # same treatment.
    svc = build_service()
    posts = [BetaPosterior(alpha=float(a), beta=float(b))
             for a, b in svc.posterior_snapshot()]
    rounds = 10
    batches = []
    for B in batch_sizes:
        # many short rounds: co-tenant CPU bursts last longer than one
        # round, so the min reliably lands in a quiet window
        reps_eff = max(4, min(reps, 4096 // max(1, B)))
        req = requests(B)
        # the packed hot path: a production batcher accumulates requests
        # into exactly this block between ticks, so the timed loop hands
        # it over zero-copy (the scalar loop likewise receives its
        # ready-made per-request args); the block is built in the
        # service's working dtype so the timed executable is the real
        # zero-copy one even under process-wide x64
        import jax

        fdtype = np.dtype(
            "float64" if jax.config.jax_enable_x64 else "float32")
        row_packed = req["rows"].astype(np.int32)
        reqs_packed = np.zeros((B, 7), fdtype)
        for j, key in enumerate(("alpha", "lam", "lat")):
            reqs_packed[:, j] = req[key]
        reqs_packed[:, 3], reqs_packed[:, 4] = 32, 160
        reqs_packed[:, 5], reqs_packed[:, 6] = pricing_in, pricing_out
        svc.tick_packed(row_packed, reqs_packed)    # warm the executable
        svc.tick_packed(row_packed, reqs_packed)
        tick_s = float("inf")
        for _ in range(rounds):
            t0 = time.perf_counter()
            for _ in range(reps_eff):
                d = svc.tick_packed(row_packed, reqs_packed)
                d.speculate                     # per-tick host round-trip
            tick_s = min(tick_s, (time.perf_counter() - t0) / reps_eff)

        args = [(posts[int(req["rows"][i])], float(req["alpha"][i]),
                 float(req["lam"][i]), float(req["lat"][i]))
                for i in range(B)]
        for a in args[: min(B, 8)]:             # warm scalar caches
            runner.decide(*a)
        scalar_s = float("inf")
        for _ in range(rounds):
            t0 = time.perf_counter()
            for _ in range(reps_eff):
                for a in args:
                    runner.decide(*a)
            scalar_s = min(scalar_s, (time.perf_counter() - t0) / reps_eff)

        batches.append({
            "B": int(B),
            "reps": reps_eff,               # actual warm reps per round
            "ticks_per_s": 1.0 / tick_s,
            "us_per_decision": tick_s / B * 1e6,
            "scalar_us_per_decision": scalar_s / B * 1e6,
            "speedup": scalar_s / tick_s,
        })

    record = {
        "benchmark": "online_decision_service",
        "rows": n_rows,
        "reps": reps,                   # requested cap; per-batch rows
        "rounds": rounds,               # carry the actual reps used
        "parity": {
            "bitwise_f64_vs_scalar_evaluate": True,
            "lower_bound_flags_match": True,
        },
        "batches": batches,
    }
    if require_speedup is not None:
        top = batches[-1]
        if top["speedup"] < require_speedup:
            raise AssertionError(
                f"online service speedup at B={top['B']} is "
                f"{top['speedup']:.1f}x < required {require_speedup}x")
    return record


_BEAM_SHARED_STATS = (
    "makespan_s", "total_cost_usd", "waste_usd", "launched", "committed",
    "EV_usd", "threshold_usd", "speculate", "edge_launched",
    "edge_committed", "edge_waste_usd", "start_s", "finish_s",
    "post_alpha", "post_beta",
)


def beam_record(alphas=DEFAULT_ALPHAS, episodes: int = 200,
                seed: int = SEED, widths=(1, 2, 4),
                candidates: int = 3) -> dict:
    """The BENCH_fleet.json ``beam`` section: the top-k speculation engine
    (repro.core.beam) on the AutoReply log, sweeping beam width as the
    third grid axis in one jit'd call.

    Two parity gates run before any timing is reported, mirroring the
    tier-1 suite (tests/test_beam.py):

    1. single-candidate discipline — the ``width == 1`` slice of the beam
       replay on the classic (no-beam-confidence) lowering is bitwise-f64
       equal to ``fleet_replay`` on every shared statistic;
    2. wide-beam twin — widths > 1 on the real top-``candidates`` intent
       beam (confidences = the Zipf head of the §7.6 running example)
       match the pure-numpy ``reference_beam_replay``: decisions, counts,
       ranks and event times bitwise, USD stats inside 1-ULP FMA
       tolerance.

    The hit rank of each episode is the drawn intent's index in the
    confidence-sorted candidate list (rank >= candidates -> miss), so a
    wider beam converts exactly the tail-intent episodes into commits —
    the Pareto rows published here attribute every launched candidate
    (``launched_candidates`` / ``cancelled_candidates``) in USD."""
    from jax.experimental import enable_x64

    from repro.core import (
        beam_replay,
        hit_rank_from_success,
        reference_beam_replay,
    )

    alphas_arr = np.asarray(alphas)
    widths = tuple(int(w) for w in widths)
    conf = {("classifier", "drafter"): tuple(PROBS[:candidates])}
    draws = _draws(episodes, seed)

    # --- parity gate 1 (f64): w=1 beam path bitwise vs fleet_replay on
    # the classic single-candidate lowering, before any timing claim.
    with enable_x64():
        lowered, success, _ = _autoreply_fleet(episodes, seed)
        ref = fleet_replay(lowered, success, alphas_arr, BEAM_LAMBDA_USD_PER_S)
        rep1 = beam_replay(lowered, hit_rank_from_success(success),
                           alphas_arr, BEAM_LAMBDA_USD_PER_S, [1])
        sl = rep1.width_slice(0)
        for name in _BEAM_SHARED_STATS:
            if not np.array_equal(sl[name], getattr(ref, name)):
                raise AssertionError(
                    f"beam w=1 parity broke vs fleet_replay: field {name}")
        del ref, rep1, sl

    # --- parity gate 2 (f64): the wide-beam sweep vs its pure-numpy
    # reference twin on the real intent beam.
    with enable_x64():
        lowered, _, vi = _autoreply_fleet(episodes, seed,
                                          beam_confidences=conf)
        hit = np.full((episodes, lowered.n_ops), -1, np.int32)
        hit[:, vi] = np.where(draws < candidates, draws, -1)
        rep = beam_replay(lowered, hit, alphas_arr, BEAM_LAMBDA_USD_PER_S,
                          list(widths))
        twin = reference_beam_replay(lowered, hit, alphas_arr,
                                     BEAM_LAMBDA_USD_PER_S, list(widths))
        for name in ("speculate", "w_eff", "edge_launched",
                     "edge_committed", "launched", "committed",
                     "launched_candidates", "cancelled_candidates",
                     "start_s", "finish_s", "makespan_s",
                     "post_alpha", "post_beta"):
            if not np.array_equal(getattr(rep, name), twin[name]):
                raise AssertionError(
                    f"beam reference parity broke: field {name}")
        ref_rel = 0.0
        for name in ("EV_usd", "threshold_usd", "edge_waste_usd",
                     "waste_usd", "total_cost_usd"):
            a, b = np.asarray(getattr(rep, name)), np.asarray(twin[name])
            rel = float(np.max(np.abs(a - b)
                               / np.maximum(np.abs(b), 1e-300)))
            ref_rel = max(ref_rel, rel)
            if rel > 1e-12:
                raise AssertionError(
                    f"beam reference drifted past ULP tolerance: "
                    f"{name} rel {rel:.2e}")
        pareto = rep.pareto()

    # --- then speed (fleet default dtype): one call sweeping all widths
    # vs one beam_replay call per width.
    lowered, _, vi = _autoreply_fleet(episodes, seed,
                                      beam_confidences=conf)
    beam_replay(lowered, hit, alphas_arr, BEAM_LAMBDA_USD_PER_S,
                list(widths))                                  # warm-up
    t0 = time.perf_counter()
    beam_replay(lowered, hit, alphas_arr, BEAM_LAMBDA_USD_PER_S, list(widths))
    one_call_s = time.perf_counter() - t0

    for w in widths:                                           # warm-up
        beam_replay(lowered, hit, alphas_arr, BEAM_LAMBDA_USD_PER_S, [w])
    t0 = time.perf_counter()
    for w in widths:
        beam_replay(lowered, hit, alphas_arr, BEAM_LAMBDA_USD_PER_S, [w])
    per_width_s = time.perf_counter() - t0

    return {
        "benchmark": "autoreply_beam_width_sweep",
        "widths": list(widths),
        "candidates": candidates,
        "confidences": list(PROBS[:candidates]),
        "lambda_usd_per_s": BEAM_LAMBDA_USD_PER_S,
        "episodes": episodes,
        "grid_points": len(alphas_arr),
        "one_call_s": one_call_s,
        "per_width_calls_s": per_width_s,
        "speedup": per_width_s / one_call_s,
        "parity": {
            "w1_bitwise_f64_vs_fleet_replay": True,
            "reference_decisions_bitwise": True,
            "reference_max_rel_error": ref_rel,
        },
        "pareto_dtype": "float64",
        "pareto": {
            str(w): {
                str(a): {
                    "latency_s": float(pareto["latency_s"][wi, gi]),
                    "cost_usd": float(pareto["cost_usd"][wi, gi]),
                    "waste_usd": float(pareto["waste_usd"][wi, gi]),
                    "launched": int(pareto["launched"][wi, gi]),
                    "committed": int(pareto["committed"][wi, gi]),
                    "launched_candidates": float(
                        pareto["launched_candidates"][wi, gi]),
                    "cancelled_candidates": float(
                        pareto["cancelled_candidates"][wi, gi]),
                }
                for gi, a in enumerate(alphas)
            }
            for wi, w in enumerate(widths)
        },
    }


def fleet_speedup(alphas=DEFAULT_ALPHAS, episodes: int = 200,
                  seed: int = SEED, *, write: bool = True,
                  tenants: int = 8, scaling_devices=(1, 2, 4, 8),
                  episode_sharded_episodes: int = 1_000_000,
                  episode_sharded_segments: int = 8,
                  online_batch_sizes=(1, 64, 1024),
                  online_rows: int = 64,
                  online_reps: int = 20,
                  online_require_speedup: float | None = 20.0,
                  beam_widths=(1, 2, 4)) -> dict:
    """Measure scalar vs fleet wall time on the identical sweep — both the
    posterior-mean gate and the §7.5 credible-bound gate — plus the
    multi-tenant sharded-engine and online-decision-service records, and
    persist everything to BENCH_fleet.json (``write=False`` returns the
    record without touching the file — the --smoke path).  Methodology
    (EXPERIMENTS.md §Perf): jit warm-up excluded, identical inputs, parity
    asserted before timing is reported.

    The published ``pareto_fleet`` rows (and the parity gate feeding them)
    run under ``enable_x64`` so the numbers sit in the same dtype tier as
    the bitwise-f64 parity claims next to them (``pareto_dtype`` labels
    the row); the *timed* sweeps stay at the fleet default dtype, matching
    every historical speedup row.  The cross-dtype launch/commit equality
    the timing relies on holds because this workload's decision margins —
    |EV - threshold| ~1e-2 relative — sit orders above both the f32 mean
    error and the ~1e-5 f32 quantile error."""
    from jax.experimental import enable_x64

    n_runs = len(alphas) * episodes

    t0 = time.perf_counter()
    scalar = sweep(alphas, episodes, seed)
    scalar_s = time.perf_counter() - t0

    # parity + published pareto rows at f64 (the scalar sweep is plain
    # Python/scipy and therefore dtype-independent — one run serves both
    # the timing above and this parity gate)
    with enable_x64():
        fleet = fleet_sweep(alphas, episodes, seed)
    parity = assert_pareto_parity(scalar, fleet, alphas)

    # warm up the jit cache at the timed shape (the episode count is a
    # traced scan length, so only a full-size call compiles the right
    # executable)
    fleet_sweep(alphas, episodes, seed)
    t0 = time.perf_counter()
    fleet32 = fleet_sweep(alphas, episodes, seed)
    fleet_s = time.perf_counter() - t0
    # the run that produced the published timing is itself parity-checked
    # at its own (f32) dtype — the f64 gate above covers the published
    # pareto rows, this one covers the timed executable
    parity_f32 = assert_pareto_parity(scalar, fleet32, alphas)

    # §7.5 conservative mode: the scalar path pays a scipy beta.ppf per
    # Phase-2 decision; the fleet path inverts in-XLA via betaincinv.
    t0 = time.perf_counter()
    scalar_lb = sweep(alphas, episodes, seed, use_lower_bound=True)
    scalar_lb_s = time.perf_counter() - t0

    with enable_x64():
        fleet_lb = fleet_sweep(alphas, episodes, seed, use_lower_bound=True)
    parity_lb = assert_pareto_parity(scalar_lb, fleet_lb, alphas)

    fleet_sweep(alphas, episodes, seed, use_lower_bound=True)  # warm-up
    t0 = time.perf_counter()
    fleet_lb32 = fleet_sweep(alphas, episodes, seed, use_lower_bound=True)
    fleet_lb_s = time.perf_counter() - t0
    parity_lb32 = assert_pareto_parity(scalar_lb, fleet_lb32, alphas)

    record = {
        "benchmark": "autoreply_alpha_sweep",
        "alphas": list(alphas),
        "lambda_usd_per_s": LAMBDA_USD_PER_S,
        "episodes": episodes,
        "grid_points": len(alphas),
        "scalar_total_s": scalar_s,
        "fleet_total_s": fleet_s,
        "scalar_us_per_episode": scalar_s / n_runs * 1e6,
        "fleet_us_per_episode": fleet_s / n_runs * 1e6,
        "speedup": scalar_s / fleet_s,
        "parity": {
            "max_rel_error": parity["max_rel_error"],
            "timed_f32_max_rel_error": parity_f32["max_rel_error"],
            "launched_match": True,
            "committed_match": True,
        },
        "pareto_dtype": "float64",
        "pareto_fleet": {
            str(a): fleet[a] for a in alphas
        },
        "credible_bound": {
            "benchmark": "autoreply_alpha_sweep_lower_bound",
            "gamma": 0.1,
            "scalar_total_s": scalar_lb_s,
            "fleet_total_s": fleet_lb_s,
            "scalar_us_per_episode": scalar_lb_s / n_runs * 1e6,
            "fleet_us_per_episode": fleet_lb_s / n_runs * 1e6,
            "speedup": scalar_lb_s / fleet_lb_s,
            "parity": {
                "max_rel_error": parity_lb["max_rel_error"],
                "timed_f32_max_rel_error": parity_lb32["max_rel_error"],
                "launched_match": True,
                "committed_match": True,
            },
            "pareto_dtype": "float64",
            "pareto_fleet": {
                str(a): fleet_lb[a] for a in alphas
            },
        },
        "multi_tenant": multi_tenant_record(
            tenants=tenants, alphas=alphas, episodes=episodes, seed=seed,
            scaling_devices=scaling_devices,
        ),
        "episode_sharded": episode_sharded_record(
            episodes=episode_sharded_episodes, alphas=alphas, seed=seed,
            segments=episode_sharded_segments,
            scaling_devices=scaling_devices,
        ),
        "online_service": online_service_record(
            batch_sizes=online_batch_sizes, n_rows=online_rows,
            reps=online_reps, seed=seed,
            require_speedup=online_require_speedup,
        ),
        "beam": beam_record(
            alphas=alphas, episodes=episodes, seed=seed,
            widths=beam_widths,
        ),
    }
    if write:
        BENCH_PATH.write_text(json.dumps(record, indent=2) + "\n")
    return record


def smoke() -> dict:
    """benchmarks/run.py --smoke: the full BENCH_fleet.json record shape at
    tiny episode counts — every parity gate runs (scalar<->fleet Pareto,
    bitwise multi-tenant), no timing claims are made, and nothing is
    written to disk.  Wired into a fast pytest
    (tests/test_benchmarks_smoke.py) so schema or parity drift breaks
    tier-1 instead of rotting until the next manual benchmark run."""
    return fleet_speedup(
        alphas=(0.0, 0.5, 0.9, 1.0), episodes=24,
        write=False, tenants=3, scaling_devices=(),
        episode_sharded_episodes=48, episode_sharded_segments=3,
        online_batch_sizes=(1, 8), online_rows=8, online_reps=3,
        online_require_speedup=None, beam_widths=(1, 2, 3),
    )


def benchmarks() -> list[tuple[str, float, str]]:
    rows = []
    t0 = time.perf_counter()
    res = sweep()
    dt = (time.perf_counter() - t0) * 1e6 / 200
    ctrl = res["control"]
    best = res[0.9]
    rows.append((
        "workflow_alpha_sweep", dt,
        f"control={ctrl['latency_s']:.2f}s alpha0.9={best['latency_s']:.2f}s "
        f"waste=${best['waste_usd']:.4f} committed={best['committed']}/{best['launched']}",
    ))
    record = fleet_speedup()
    rows.append((
        "workflow_fleet_replay", record["fleet_us_per_episode"],
        f"speedup={record['speedup']:.0f}x vs scalar "
        f"({record['scalar_us_per_episode']:.0f}us/ep -> "
        f"{record['fleet_us_per_episode']:.2f}us/ep), "
        f"parity max_rel={record['parity']['max_rel_error']:.1e}",
    ))
    lb = record["credible_bound"]
    rows.append((
        "workflow_fleet_replay_lower_bound", lb["fleet_us_per_episode"],
        f"speedup={lb['speedup']:.0f}x vs scalar "
        f"({lb['scalar_us_per_episode']:.0f}us/ep -> "
        f"{lb['fleet_us_per_episode']:.2f}us/ep), "
        f"parity max_rel={lb['parity']['max_rel_error']:.1e}",
    ))
    mt = record["multi_tenant"]
    n_ep = mt["tenants"] * mt["grid_points"] * mt["episodes"]
    scaling = " ".join(
        f"{r['devices']}dev={r['wall_s'] * 1e3:.0f}ms"
        for r in mt["scaling"]
    )
    rows.append((
        "workflow_multi_tenant_replay", mt["one_call_s"] / n_ep * 1e6,
        f"{mt['tenants']}T x {mt['grid_points']}G x {mt['episodes']}E in one "
        f"call; {mt['speedup']:.1f}x vs {mt['tenants']} fleet_replay calls; "
        f"bitwise-f64 per-tenant parity; scaling {scaling or 'n/a'}",
    ))
    es = record["episode_sharded"]
    n_es = es["episodes"] * es["grid_points"]
    es_scaling = " ".join(
        f"{r['devices']}dev={r['wall_s']:.1f}s" for r in es["scaling"]
    )
    rows.append((
        "workflow_episode_sharded_replay", es["sharded_s"] / n_es * 1e6,
        f"{es['episodes']}E x {es['grid_points']}G as {es['segments']} "
        f"segments; bitwise-f64 parity vs fleet_replay pre-timing; "
        f"{es['speedup']:.2f}x vs unsharded scan on one device (segment-"
        f"vmap cuts scan depth); scaling {es_scaling or 'n/a'}",
    ))
    os_rec = record["online_service"]
    top = os_rec["batches"][-1]
    per_b = " ".join(
        f"B{b['B']}={b['us_per_decision']:.2f}us/dec({b['speedup']:.0f}x)"
        for b in os_rec["batches"]
    )
    rows.append((
        "online_decision_service", top["us_per_decision"],
        f"{os_rec['rows']} rows; bitwise-f64 decide parity pre-timing; "
        f"{top['ticks_per_s']:.0f} ticks/s at B={top['B']}; {per_b} vs "
        f"scalar decide loop",
    ))
    bm = record["beam"]
    n_bm = bm["episodes"] * bm["grid_points"] * len(bm["widths"])
    w_hi, w_lo = str(max(bm["widths"])), str(min(bm["widths"]))
    mid_a = str(DEFAULT_ALPHAS[len(DEFAULT_ALPHAS) // 2])
    rows.append((
        "workflow_beam_width_sweep", bm["one_call_s"] / n_bm * 1e6,
        f"widths {bm['widths']} x {bm['grid_points']}G x {bm['episodes']}E "
        f"in one call; w=1 bitwise-f64 vs fleet_replay pre-timing; "
        f"{bm['speedup']:.1f}x vs per-width calls; committed@alpha{mid_a} "
        f"w{w_lo}->{w_hi}: {bm['pareto'][w_lo][mid_a]['committed']}->"
        f"{bm['pareto'][w_hi][mid_a]['committed']}",
    ))
    return rows
