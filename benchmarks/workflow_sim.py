"""End-to-end workflow simulation: the AutoReply scenario through the full
planner + executor, sweeping alpha (§12.3 canary sweep, simulated).

Two implementations of the same sweep:

* ``sweep``        — paper-faithful scalar path: one discrete-event
  ``execute`` call per episode (200 deterministic episodes per alpha; the
  upstream classifier emits an intent from a Zipf-ish 5-way distribution
  with p_mode = 0.62, §7.6's running example).
* ``fleet_sweep``  — the vectorized replay engine (repro.core.fleet): all
  episodes x all alphas in one jit-compiled XLA call.

``benchmarks()`` runs both, asserts the Pareto statistics agree, and
persists the speedup record to BENCH_fleet.json (machine-readable perf
trajectory across PRs; see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from repro.core import (
    DependencyType,
    Edge,
    ExecutorConfig,
    Operation,
    PlannerParams,
    Workflow,
    execute,
    fleet_replay,
    lower_workflow,
    plan_workflow,
)
from repro.core.posterior import BetaPosterior
from repro.core.predictor import HistoricalModalPredictor

INTENTS = ["billing", "support", "sales", "spam", "other"]
PROBS = [0.62, 0.12, 0.10, 0.09, 0.07]
DEFAULT_ALPHAS = (0.0, 0.1, 0.3, 0.5, 0.7, 0.9, 1.0)
LAMBDA_USD_PER_S = 0.08
SEED = 20260531
BENCH_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_fleet.json"


def build_workflow(intent: str) -> Workflow:
    wf = Workflow("autoreply")
    wf.add_op(Operation(
        "classifier", run=lambda x: intent, latency_est_s=0.8,
        input_tokens_est=200, output_tokens_est=10,
        metadata={"input": "email", "chunks": 8},
    ))
    wf.add_op(Operation(
        "drafter", run=lambda i: f"draft[{i}]", latency_est_s=0.8,
        input_tokens_est=500, output_tokens_est=800,
    ))
    wf.add_edge(Edge("classifier", "drafter",
                     dep_type=DependencyType.ROUTER_K_WAY, k=5))
    return wf.freeze()


def _draws(episodes: int, seed: int = SEED) -> np.ndarray:
    return np.random.default_rng(seed).choice(
        len(INTENTS), size=episodes, p=PROBS
    )


def sweep(alphas=DEFAULT_ALPHAS, episodes: int = 200,
          seed: int = SEED, *, use_lower_bound: bool = False,
          gamma: float = 0.1) -> dict:
    """Paper-faithful scalar sweep: plan + execute per episode.

    ``use_lower_bound=True`` runs the §7.5 conservative variant: both the
    planner and the Phase-2 runtime gate on the one-sided (1-gamma) lower
    credible bound instead of the posterior mean."""
    draws = _draws(episodes, seed)
    results = {}
    for alpha in alphas:
        post = BetaPosterior.from_dependency_type(DependencyType.ROUTER_K_WAY, k=5)
        lat, cost, waste, committed, launched = [], [], [], 0, 0
        for e in range(episodes):
            intent = INTENTS[draws[e]]
            wf = build_workflow(intent)
            params = PlannerParams(
                alpha=alpha, lambda_usd_per_s=LAMBDA_USD_PER_S,
                posteriors={("classifier", "drafter"): post},
                use_lower_bound=use_lower_bound, gamma=gamma,
            )
            plan, _ = plan_workflow(wf, params)
            pred = HistoricalModalPredictor()
            pred.observe("email", "billing")   # modal prediction
            cfg = ExecutorConfig(params=params,
                                 predictors={("classifier", "drafter"): pred},
                                 use_lower_bound=use_lower_bound,
                                 gamma=gamma)
            rep = execute(wf, plan, cfg)
            lat.append(rep.makespan_s)
            cost.append(rep.total_cost_usd)
            waste.append(rep.waste_usd)
            launched += sum(o.launched for o in rep.outcomes)
            committed += sum(o.committed for o in rep.outcomes)
        results[alpha] = {
            "latency_s": float(np.mean(lat)),
            "cost_usd": float(np.mean(cost)),
            "waste_usd": float(np.mean(waste)),
            "launched": launched,
            "committed": committed,
            "posterior_final": post.mean,
        }
    # sequential control arm
    wf = build_workflow("billing")
    results["control"] = {
        "latency_s": wf.sequential_latency(),
        "cost_usd": sum(
            op.input_tokens_est * 3e-6 + op.output_tokens_est * 15e-6
            for op in wf.ops.values()
        ),
        "waste_usd": 0.0,
    }
    return results


def fleet_sweep(alphas=DEFAULT_ALPHAS, episodes: int = 200,
                seed: int = SEED, *, use_lower_bound: bool = False,
                gamma: float = 0.1) -> dict:
    """The same sweep through the vectorized fleet replay engine: one
    XLA call for all episodes x alphas.  ``use_lower_bound=True`` gates
    on the jax-native betaincinv credible bound inside that same call."""
    draws = _draws(episodes, seed)
    wf = build_workflow("billing")
    edge_key = ("classifier", "drafter")
    params = PlannerParams(
        alpha=0.5, lambda_usd_per_s=LAMBDA_USD_PER_S,
        posteriors={edge_key: BetaPosterior.from_dependency_type(
            DependencyType.ROUTER_K_WAY, k=5)},
        use_lower_bound=use_lower_bound, gamma=gamma,
    )
    pred = HistoricalModalPredictor()
    pred.observe("email", "billing")
    lowered = lower_workflow(wf, params, predictors={edge_key: pred})
    vi = lowered.names.index("drafter")
    success = np.zeros((episodes, lowered.n_ops), bool)
    success[:, vi] = draws == 0        # modal prediction is "billing"
    report = fleet_replay(lowered, success, np.asarray(alphas),
                          LAMBDA_USD_PER_S)
    results = {}
    for gi, alpha in enumerate(alphas):
        results[alpha] = {
            "latency_s": float(report.makespan_s[:, gi].mean()),
            "cost_usd": float(report.total_cost_usd[:, gi].mean()),
            "waste_usd": float(report.waste_usd[:, gi].mean()),
            "launched": int(report.launched[:, gi].sum()),
            "committed": int(report.committed[:, gi].sum()),
            "posterior_final": float(
                report.post_alpha[-1, gi, vi]
                / (report.post_alpha[-1, gi, vi] + report.post_beta[-1, gi, vi])
            ),
        }
    return results


def assert_pareto_parity(scalar: dict, fleet: dict, alphas=DEFAULT_ALPHAS,
                         rtol: float = 1e-4) -> dict:
    """The fleet path must reproduce the scalar AutoReply Pareto: identical
    launch/commit counts, matching latency/cost/waste means."""
    worst = 0.0
    for alpha in alphas:
        s, f = scalar[alpha], fleet[alpha]
        if s["launched"] != f["launched"] or s["committed"] != f["committed"]:
            raise AssertionError(
                f"fleet/scalar divergence at alpha={alpha}: "
                f"launched {s['launched']}!={f['launched']} or committed "
                f"{s['committed']}!={f['committed']}"
            )
        for key in ("latency_s", "cost_usd", "waste_usd"):
            denom = max(abs(s[key]), 1e-12)
            rel = abs(s[key] - f[key]) / denom
            worst = max(worst, rel)
            if rel > rtol:
                raise AssertionError(
                    f"fleet/scalar divergence at alpha={alpha} {key}: "
                    f"{s[key]} vs {f[key]} (rel {rel:.2e})"
                )
    return {"max_rel_error": worst}


def fleet_speedup(alphas=DEFAULT_ALPHAS, episodes: int = 200,
                  seed: int = SEED) -> dict:
    """Measure scalar vs fleet wall time on the identical sweep — both the
    posterior-mean gate and the §7.5 credible-bound gate — and persist the
    record to BENCH_fleet.json.  Methodology (EXPERIMENTS.md §Perf): jit
    warm-up excluded, identical inputs, parity asserted before timing is
    reported.  The parity contract (exact launch/commit counts between
    the f64 scalar gate and the f32 fleet gate) relies on this workload's
    decision margins — |EV - threshold| is ~1e-2 relative here, orders
    above both the f32 mean error and the ~1e-5 f32 quantile error, same
    as the pre-existing mean-gate record."""
    n_runs = len(alphas) * episodes

    t0 = time.perf_counter()
    scalar = sweep(alphas, episodes, seed)
    scalar_s = time.perf_counter() - t0

    # warm up the jit cache at the timed shape (the episode count is a
    # traced scan length, so only a full-size call compiles the right
    # executable)
    fleet_sweep(alphas, episodes, seed)
    t0 = time.perf_counter()
    fleet = fleet_sweep(alphas, episodes, seed)
    fleet_s = time.perf_counter() - t0

    parity = assert_pareto_parity(scalar, fleet, alphas)

    # §7.5 conservative mode: the scalar path pays a scipy beta.ppf per
    # Phase-2 decision; the fleet path inverts in-XLA via betaincinv.
    t0 = time.perf_counter()
    scalar_lb = sweep(alphas, episodes, seed, use_lower_bound=True)
    scalar_lb_s = time.perf_counter() - t0

    fleet_sweep(alphas, episodes, seed, use_lower_bound=True)  # warm-up
    t0 = time.perf_counter()
    fleet_lb = fleet_sweep(alphas, episodes, seed, use_lower_bound=True)
    fleet_lb_s = time.perf_counter() - t0

    parity_lb = assert_pareto_parity(scalar_lb, fleet_lb, alphas)

    record = {
        "benchmark": "autoreply_alpha_sweep",
        "alphas": list(alphas),
        "lambda_usd_per_s": LAMBDA_USD_PER_S,
        "episodes": episodes,
        "grid_points": len(alphas),
        "scalar_total_s": scalar_s,
        "fleet_total_s": fleet_s,
        "scalar_us_per_episode": scalar_s / n_runs * 1e6,
        "fleet_us_per_episode": fleet_s / n_runs * 1e6,
        "speedup": scalar_s / fleet_s,
        "parity": {
            "max_rel_error": parity["max_rel_error"],
            "launched_match": True,
            "committed_match": True,
        },
        "pareto_fleet": {
            str(a): fleet[a] for a in alphas
        },
        "credible_bound": {
            "benchmark": "autoreply_alpha_sweep_lower_bound",
            "gamma": 0.1,
            "scalar_total_s": scalar_lb_s,
            "fleet_total_s": fleet_lb_s,
            "scalar_us_per_episode": scalar_lb_s / n_runs * 1e6,
            "fleet_us_per_episode": fleet_lb_s / n_runs * 1e6,
            "speedup": scalar_lb_s / fleet_lb_s,
            "parity": {
                "max_rel_error": parity_lb["max_rel_error"],
                "launched_match": True,
                "committed_match": True,
            },
            "pareto_fleet": {
                str(a): fleet_lb[a] for a in alphas
            },
        },
    }
    BENCH_PATH.write_text(json.dumps(record, indent=2) + "\n")
    return record


def benchmarks() -> list[tuple[str, float, str]]:
    rows = []
    t0 = time.perf_counter()
    res = sweep()
    dt = (time.perf_counter() - t0) * 1e6 / 200
    ctrl = res["control"]
    best = res[0.9]
    rows.append((
        "workflow_alpha_sweep", dt,
        f"control={ctrl['latency_s']:.2f}s alpha0.9={best['latency_s']:.2f}s "
        f"waste=${best['waste_usd']:.4f} committed={best['committed']}/{best['launched']}",
    ))
    record = fleet_speedup()
    rows.append((
        "workflow_fleet_replay", record["fleet_us_per_episode"],
        f"speedup={record['speedup']:.0f}x vs scalar "
        f"({record['scalar_us_per_episode']:.0f}us/ep -> "
        f"{record['fleet_us_per_episode']:.2f}us/ep), "
        f"parity max_rel={record['parity']['max_rel_error']:.1e}",
    ))
    lb = record["credible_bound"]
    rows.append((
        "workflow_fleet_replay_lower_bound", lb["fleet_us_per_episode"],
        f"speedup={lb['speedup']:.0f}x vs scalar "
        f"({lb['scalar_us_per_episode']:.0f}us/ep -> "
        f"{lb['fleet_us_per_episode']:.2f}us/ep), "
        f"parity max_rel={lb['parity']['max_rel_error']:.1e}",
    ))
    return rows
