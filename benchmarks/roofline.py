"""Roofline benchmark: reads the dry-run artifacts and prints the
per-(arch x shape) three-term table (EXPERIMENTS.md §Roofline source)."""
from __future__ import annotations

import json
from pathlib import Path

ARTIFACT_DIR = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_cells(tag: str = "") -> list[dict]:
    cells = []
    if not ARTIFACT_DIR.exists():
        return cells
    for p in sorted(ARTIFACT_DIR.glob("*.json")):
        if "multipod" in p.name:
            continue
        if tag and not p.stem.endswith(f"_{tag}"):
            continue
        if not tag and any(p.stem.endswith(s) for s in ("_scatter", "_triangular", "_nofsdp", "_noremat", "_absorbed")):
            continue
        cells.append(json.loads(p.read_text()))
    return cells


def terms_of(cell: dict) -> dict:
    """Recompute the three roofline terms from the raw artifact numbers
    (memory term = analytic TPU traffic; the CPU-pipeline HLO bytes are kept
    as a secondary column — see EXPERIMENTS.md §Roofline caveat)."""
    r = cell["roofline"]
    peak, hbm, ici = 197e12, 819e9, 50e9
    flops_dev = r["compute_s"] * peak            # invert stored term
    coll_s = r["collective_s"]
    mem_analytic_s = r.get("memory_s_analytic_tpu",
                           r["hbm_bytes_analytic_per_device"] / hbm
                           if "hbm_bytes_analytic_per_device" in r else r["memory_s"])
    mem_hlo_s = r.get("memory_s_hlo_cpu", r["memory_s"])
    terms = {"compute_s": r["compute_s"], "memory_s": mem_analytic_s,
             "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    return {**terms, "memory_s_hlo_cpu": mem_hlo_s, "dominant": dom,
            "bound_s": bound, "useful_ratio": r["useful_flops_ratio"],
            "flops_dev": flops_dev}


def table(tag: str = "") -> str:
    rows = ["arch,shape,dominant,compute_s,memory_s,collective_s,"
            "useful_ratio,fits_16gb,skipped"]
    for c in load_cells(tag):
        if c.get("skipped"):
            rows.append(f"{c['arch']},{c['shape']},skip,,,,,,{c['reason'][:40]}")
            continue
        if "roofline" not in c:
            continue
        t = terms_of(c)
        rows.append(
            f"{c['arch']},{c['shape']},{t['dominant']},{t['compute_s']:.4g},"
            f"{t['memory_s']:.4g},{t['collective_s']:.4g},"
            f"{t['useful_ratio']:.3f},"
            f"{c['memory_analysis']['fits_16gb']},"
        )
    return "\n".join(rows)


def benchmarks() -> list[tuple[str, float, str]]:
    cells = [c for c in load_cells() if not c.get("skipped") and "roofline" in c]
    if not cells:
        return [("roofline_table", 0.0, "no dry-run artifacts yet")]
    n_fit = sum(c["memory_analysis"]["fits_16gb"] for c in cells)
    worst = min(cells, key=lambda c: c["roofline"]["useful_flops_ratio"])
    return [(
        "roofline_table", float(len(cells)),
        f"cells={len(cells)} fit={n_fit} worst_ratio="
        f"{worst['arch']}/{worst['shape']}:{worst['roofline']['useful_flops_ratio']:.3f}",
    )]
