"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per benchmark).

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys


def main() -> None:
    from . import appendix_d, paper_tables, perf, roofline, workflow_sim

    rows: list[tuple[str, float, str]] = []
    for mod in (paper_tables, appendix_d, workflow_sim, perf, roofline):
        rows.extend(mod.benchmarks())
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main()
