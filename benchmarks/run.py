"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per benchmark) and
persists every module's rows to ``BENCH_<module>.json`` at the repo root
so the perf trajectory is machine-readable across PRs (the fleet replay
additionally writes its own BENCH_fleet.json speedup record from
``workflow_sim.fleet_speedup``).

    PYTHONPATH=src python -m benchmarks.run            # full run
    PYTHONPATH=src python -m benchmarks.run --smoke    # CI mode

``--smoke`` runs the fleet record at tiny episode counts: every parity
gate still executes (scalar<->fleet Pareto, bitwise multi-tenant) and
both the fresh record and the checked-in BENCH_*.json files are
schema-validated, but no timings are asserted and nothing is written —
tests/test_benchmarks_smoke.py keeps it in tier-1 so benchmark drift
breaks fast instead of rotting silently.
"""
from __future__ import annotations

import json
import pathlib
import platform
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]

# BENCH_fleet.json schema (see workflow_sim.fleet_speedup): required keys
# at each level of the record.
_FLEET_KEYS = {
    "benchmark", "alphas", "episodes", "grid_points", "scalar_total_s",
    "fleet_total_s", "speedup", "parity", "pareto_dtype", "pareto_fleet",
    "credible_bound", "multi_tenant", "episode_sharded", "online_service",
    "beam",
}
_CREDIBLE_KEYS = {"benchmark", "gamma", "speedup", "parity", "pareto_dtype",
                  "pareto_fleet"}
_OS_KEYS = {"benchmark", "rows", "reps", "rounds", "parity", "batches"}
_OS_BATCH_KEYS = {"B", "reps", "ticks_per_s", "us_per_decision",
                  "scalar_us_per_decision", "speedup"}
_MT_KEYS = {
    "benchmark", "tenants", "grid_points", "episodes", "one_call_s",
    "per_tenant_calls_s", "speedup", "parity", "scaling",
}
_ES_KEYS = {
    "benchmark", "episodes", "segments", "grid_points", "unsharded_s",
    "sharded_s", "speedup", "parity", "scaling", "pipelined",
}
_ES_PIPE_KEYS = {"pipelined_s", "speedup_vs_two_pass",
                 "speedup_vs_unsharded", "parity"}
_BEAM_KEYS = {
    "benchmark", "widths", "candidates", "confidences", "lambda_usd_per_s",
    "episodes", "grid_points", "one_call_s", "per_width_calls_s", "speedup",
    "parity", "pareto_dtype", "pareto",
}
_BEAM_PARETO_KEYS = {
    "latency_s", "cost_usd", "waste_usd", "launched", "committed",
    "launched_candidates", "cancelled_candidates",
}
_ROWS_KEYS = {"module", "rows"}

# BENCH_frontend.json schema (see frontend_load.frontend_record)
_FRONTEND_KEYS = {
    "benchmark", "seed", "offered_rate_hz", "duration_s", "requests",
    "config", "decisions_per_s", "shed_rate", "latency_ms", "ticks",
    "deadline_ticks", "full_ticks", "stats", "parity", "fault_matrix",
    "resilience_events", "usd_attribution",
}
_FRONTEND_FAULTS = {"exception_burst", "hung_tick", "tenant_flood",
                    "drift_flip"}

# BENCH_store.json schema (see store_scale.store_record)
_STORE_KEYS = {
    "benchmark", "seed", "logical_rows", "resident_capacity",
    "decisions_per_s", "parity", "zero_recompile", "register", "decide",
    "memory", "cold_start",
}

# BENCH_kernels.json schema (see kernels_bench.kernels_record)
_KERNELS_KEYS = {"benchmark", "backend", "interpret", "betaincinv",
                 "online_tick"}
_K_BII_KEYS = {"n", "parity", "sweep", "reference_us_per_call"}
_K_TICK_KEYS = {"rows", "batch", "settles", "parity", "sweep",
                "reference_us_per_tick"}

# BENCH_rollout.json schema (see rollout_fleet.rollout_record)
_ROLLOUT_KEYS = {
    "benchmark", "seed", "decisions_per_s", "determinism", "parity",
    "zero_recompile", "acceptance", "pareto",
}
_ROLLOUT_PARETO_KEYS = {
    "archetype", "p_mode", "speculate_rate", "success_rate",
    "final_phases", "promotes", "demotes", "demote_usd", "events",
}


def _require(present, required, what: str) -> None:
    missing = sorted(required - set(present))
    if missing:
        raise AssertionError(f"{what}: missing keys {missing}")


def validate_fleet_record(rec: dict, what: str = "fleet record") -> None:
    """Assert the BENCH_fleet.json shape (full and --smoke records)."""
    _require(rec, _FLEET_KEYS, what)
    _require(rec["credible_bound"], _CREDIBLE_KEYS, f"{what}.credible_bound")
    _require(rec["multi_tenant"], _MT_KEYS, f"{what}.multi_tenant")
    for row in rec["multi_tenant"]["scaling"]:
        _require(row, {"devices", "shards", "wall_s"},
                 f"{what}.multi_tenant.scaling")
    es = rec["episode_sharded"]
    _require(es, _ES_KEYS, f"{what}.episode_sharded")
    _require(es["parity"],
             {"bitwise_f64_vs_fleet_replay",
              "grid_reroute_fraction_bitwise",
              "grid_reroute_max_rel_error"},
             f"{what}.episode_sharded.parity")
    _require(es["pipelined"], _ES_PIPE_KEYS,
             f"{what}.episode_sharded.pipelined")
    if not es["pipelined"]["parity"].get("bitwise_f64_vs_fleet_replay"):
        raise AssertionError(
            f"{what}.episode_sharded.pipelined: parity gate recorded false")
    for row in es["scaling"]:
        _require(row, {"devices", "shards", "wall_s"},
                 f"{what}.episode_sharded.scaling")
    osvc = rec["online_service"]
    _require(osvc, _OS_KEYS, f"{what}.online_service")
    _require(osvc["parity"],
             {"bitwise_f64_vs_scalar_evaluate", "lower_bound_flags_match"},
             f"{what}.online_service.parity")
    if not osvc["batches"]:
        raise AssertionError(f"{what}.online_service: no batch rows")
    for row in osvc["batches"]:
        _require(row, _OS_BATCH_KEYS, f"{what}.online_service.batches")
    beam = rec["beam"]
    _require(beam, _BEAM_KEYS, f"{what}.beam")
    _require(beam["parity"],
             {"w1_bitwise_f64_vs_fleet_replay",
              "reference_decisions_bitwise", "reference_max_rel_error"},
             f"{what}.beam.parity")
    if not (beam["parity"]["w1_bitwise_f64_vs_fleet_replay"]
            and beam["parity"]["reference_decisions_bitwise"]):
        raise AssertionError(f"{what}.beam: parity gate recorded false")
    if not beam["widths"] or beam["widths"][0] != 1:
        raise AssertionError(
            f"{what}.beam: width sweep must start at the parity-gated "
            f"width 1, got {beam['widths']}")
    for w in beam["widths"]:
        rows = beam["pareto"].get(str(w))
        if not rows:
            raise AssertionError(f"{what}.beam: no pareto rows at w={w}")
        for a, row in rows.items():
            _require(row, _BEAM_PARETO_KEYS, f"{what}.beam.pareto[{w}][{a}]")


def validate_frontend_record(rec: dict, what: str = "frontend record") -> None:
    """Assert the BENCH_frontend.json shape (full and --smoke records)."""
    _require(rec, _FRONTEND_KEYS, what)
    _require(rec["latency_ms"], {"p50", "p99", "max"}, f"{what}.latency_ms")
    _require(rec["config"], {"max_batch", "deadline_s", "bulkhead_limit"},
             f"{what}.config")
    _require(rec["parity"],
             {"service_vs_scalar_bitwise_f64",
              "fallback_vs_scalar_bitwise_f64"},
             f"{what}.parity")
    if not (rec["parity"]["service_vs_scalar_bitwise_f64"]
            and rec["parity"]["fallback_vs_scalar_bitwise_f64"]):
        raise AssertionError(f"{what}: parity gate recorded false")
    _require(rec["fault_matrix"], _FRONTEND_FAULTS, f"{what}.fault_matrix")
    for name in _FRONTEND_FAULTS:
        _require(rec["fault_matrix"][name], {"events"},
                 f"{what}.fault_matrix.{name}")


def validate_store_record(rec: dict, what: str = "store record") -> None:
    """Assert the BENCH_store.json shape (full and --smoke records)."""
    _require(rec, _STORE_KEYS, what)
    par = rec["parity"]
    _require(par, {"paged_vs_dense_bitwise_f64",
                   "paged_vs_scalar_bitwise_f64", "rows_checked"},
             f"{what}.parity")
    if not (par["paged_vs_dense_bitwise_f64"]
            and par["paged_vs_scalar_bitwise_f64"]):
        raise AssertionError(f"{what}: parity gate recorded false")
    zr = rec["zero_recompile"]
    _require(zr, {"churn_steps", "logical_rows_end",
                  "host_capacity_doublings", "physical_capacity",
                  "rebuilds", "asserted"}, f"{what}.zero_recompile")
    if not zr["asserted"]:
        raise AssertionError(f"{what}: zero-recompile churn not asserted")
    _require(rec["register"], {"rows", "us_per_row"}, f"{what}.register")
    _require(rec["decide"], {"ticks", "batch", "us_per_decision",
                             "fault_ins", "spills"}, f"{what}.decide")
    _require(rec["memory"], {"logical_rows", "resident_rows",
                             "shelved_rows", "host_soa_bytes_per_row",
                             "device_table_bytes", "capacity"},
             f"{what}.memory")
    cs = rec["cold_start"]
    _require(cs, {"p_star", "bucket", "pooled_prior", "fixed_prior",
                  "curve", "pooled_tighter_at_birth"}, f"{what}.cold_start")
    if not cs["pooled_tighter_at_birth"]:
        raise AssertionError(
            f"{what}: pooled cold start not tighter than the fixed prior")
    if not cs["curve"]:
        raise AssertionError(f"{what}: empty cold-start curve")
    for row in cs["curve"]:
        _require(row, {"n_obs", "pooled_abs_err", "fixed_abs_err"},
                 f"{what}.cold_start.curve")


def validate_kernels_record(rec: dict, what: str = "kernels record") -> None:
    """Assert the BENCH_kernels.json shape (full and --smoke records).

    Both kernels must have recorded their parity gates as *passed*
    (parity is asserted in-process before any timing row is taken, so a
    record that exists at all implies the gates ran — this re-checks the
    recorded outcome so a hand-edited file can't smuggle a timing row
    past a failed gate)."""
    _require(rec, _KERNELS_KEYS, what)
    bii = rec["betaincinv"]
    _require(bii, _K_BII_KEYS, f"{what}.betaincinv")
    par = bii["parity"]
    _require(par, {"max_rel_vs_core", "max_rel_vs_scipy", "asserted_rtol"},
             f"{what}.betaincinv.parity")
    if not (par["max_rel_vs_core"] <= par["asserted_rtol"]
            and par["max_rel_vs_scipy"] <= par["asserted_rtol"]):
        raise AssertionError(
            f"{what}.betaincinv: recorded rel error exceeds asserted rtol")
    if not bii["sweep"]:
        raise AssertionError(f"{what}.betaincinv: empty block_n sweep")
    for row in bii["sweep"]:
        _require(row, {"block_n", "us_per_call"}, f"{what}.betaincinv.sweep")
    tick = rec["online_tick"]
    _require(tick, _K_TICK_KEYS, f"{what}.online_tick")
    tpar = tick["parity"]
    _require(tpar, {"mean_path_bitwise_f64", "lower_bound_max_rel"},
             f"{what}.online_tick.parity")
    if not tpar["mean_path_bitwise_f64"]:
        raise AssertionError(
            f"{what}.online_tick: mean-path parity gate recorded false")
    if not tick["sweep"]:
        raise AssertionError(f"{what}.online_tick: empty block_n sweep")
    for row in tick["sweep"]:
        _require(row, {"block_n", "us_per_tick"}, f"{what}.online_tick.sweep")


def validate_rollout_record(rec: dict, what: str = "rollout record") -> None:
    """Assert the BENCH_rollout.json shape (full and --smoke records)."""
    _require(rec, _ROLLOUT_KEYS, what)
    if not rec["determinism"].get("deterministic"):
        raise AssertionError(f"{what}: scenario determinism gate false")
    par = rec["parity"]
    _require(par, {"in_graph_vs_scalar_lifecycle", "ticks", "transitions",
                   "roll_state_bitwise"}, f"{what}.parity")
    if not (par["in_graph_vs_scalar_lifecycle"]
            and par["roll_state_bitwise"]):
        raise AssertionError(f"{what}: lifecycle parity gate false")
    zr = rec["zero_recompile"]
    _require(zr, {"asserted", "churn_ticks", "tick_executables",
                  "transition_kinds"}, f"{what}.zero_recompile")
    if not zr["asserted"]:
        raise AssertionError(f"{what}: zero-recompile churn not asserted")
    acc = rec["acceptance"]
    _require(acc, {"flip_at", "revert_at", "first_demote_tick",
                   "trigger_window_ticks", "demote_usd",
                   "re_promote_ticks", "final_phase", "events"},
             f"{what}.acceptance")
    if acc["final_phase"] != "FULL" or acc["demote_usd"] <= 0.0:
        raise AssertionError(f"{what}: acceptance scenario not met: {acc}")
    if not rec["pareto"]:
        raise AssertionError(f"{what}: empty Pareto table")
    for row in rec["pareto"]:
        _require(row, _ROLLOUT_PARETO_KEYS, f"{what}.pareto row")


def validate_bench_files() -> list[str]:
    """Schema-check every checked-in BENCH_*.json; returns the paths."""
    checked = []
    for path in sorted(ROOT.glob("BENCH_*.json")):
        obj = json.loads(path.read_text())
        if path.name == "BENCH_fleet.json":
            validate_fleet_record(obj, path.name)
        elif path.name == "BENCH_kernels.json":
            validate_kernels_record(obj, path.name)
        elif path.name == "BENCH_frontend.json":
            validate_frontend_record(obj, path.name)
        elif path.name == "BENCH_store.json":
            validate_store_record(obj, path.name)
        elif path.name == "BENCH_rollout.json":
            validate_rollout_record(obj, path.name)
        else:
            _require(obj, _ROWS_KEYS, path.name)
            for row in obj["rows"]:
                _require(row, {"name", "us_per_call", "derived"},
                         f"{path.name} row")
        checked.append(path.name)
    return checked


def smoke() -> dict:
    """Tiny-episode parity + schema gate (no timing claims, no writes).

    Runs the fleet record at tiny episode counts AND the serving
    front-end open-loop gate (deterministic seeded arrival trace on a
    virtual clock: parity, fault matrix, schema) AND the paged posterior
    store gate (dense/scalar bitwise parity, zero-recompile churn,
    pooled cold start) AND the staged-rollout lifecycle gate (scenario
    determinism, scalar lifecycle parity, zero-recompile phase churn,
    the acceptance flip) AND the Pallas hot-path kernel gate (interpret
    mode: betaincinv <=1e-10 vs the XLA inversion and scipy, fused tick
    bitwise vs the jitted reference tick) — all without touching any
    BENCH file."""
    from . import (frontend_load, kernels_bench, rollout_fleet, store_scale,
                   workflow_sim)

    rec = workflow_sim.smoke()
    validate_fleet_record(rec, "smoke record")
    k_rec = kernels_bench.smoke()
    validate_kernels_record(k_rec, "kernels smoke record")
    fe_rec = frontend_load.smoke()
    validate_frontend_record(fe_rec, "frontend smoke record")
    st_rec = store_scale.smoke()
    validate_store_record(st_rec, "store smoke record")
    ro_rec = rollout_fleet.smoke()
    validate_rollout_record(ro_rec, "rollout smoke record")
    checked = validate_bench_files()
    print(f"smoke ok: parity gates passed, schema ok for {checked}")
    return rec


def _persist(module_name: str, rows: list[tuple[str, float, str]]) -> None:
    out = {
        "module": module_name,
        "host": platform.machine(),
        "python": platform.python_version(),
        "unix_time": int(time.time()),
        "rows": [
            {"name": name, "us_per_call": us, "derived": derived}
            for name, us, derived in rows
        ],
    }
    path = ROOT / f"BENCH_{module_name}.json"
    path.write_text(json.dumps(out, indent=2) + "\n")


def main(only: list[str] | None = None) -> None:
    from . import (appendix_d, frontend_load, kernels_bench, paper_tables,
                   perf, rollout_fleet, roofline, store_scale, workflow_sim)

    modules = {
        "paper_tables": paper_tables,
        "appendix_d": appendix_d,
        "workflow_sim": workflow_sim,
        "perf": perf,
        "roofline": roofline,
        "frontend_load": frontend_load,
        "store_scale": store_scale,
        "rollout_fleet": rollout_fleet,
        "kernels_bench": kernels_bench,
    }
    if only:
        unknown = sorted(set(only) - set(modules))
        if unknown:
            raise SystemExit(
                f"unknown benchmark module(s) {unknown}; "
                f"known: {sorted(modules)}"
            )
        modules = {k: v for k, v in modules.items() if k in only}

    rows: list[tuple[str, float, str]] = []
    for name, mod in modules.items():
        mod_rows = mod.benchmarks()
        _persist(name, mod_rows)
        rows.extend(mod_rows)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--smoke" in argv:
        smoke()
    else:
        main(only=argv or None)
