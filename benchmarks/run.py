"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one row per benchmark) and
persists every module's rows to ``BENCH_<module>.json`` at the repo root
so the perf trajectory is machine-readable across PRs (the fleet replay
additionally writes its own BENCH_fleet.json speedup record from
``workflow_sim.fleet_speedup``).

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import json
import pathlib
import platform
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _persist(module_name: str, rows: list[tuple[str, float, str]]) -> None:
    out = {
        "module": module_name,
        "host": platform.machine(),
        "python": platform.python_version(),
        "unix_time": int(time.time()),
        "rows": [
            {"name": name, "us_per_call": us, "derived": derived}
            for name, us, derived in rows
        ],
    }
    path = ROOT / f"BENCH_{module_name}.json"
    path.write_text(json.dumps(out, indent=2) + "\n")


def main(only: list[str] | None = None) -> None:
    from . import appendix_d, paper_tables, perf, roofline, workflow_sim

    modules = {
        "paper_tables": paper_tables,
        "appendix_d": appendix_d,
        "workflow_sim": workflow_sim,
        "perf": perf,
        "roofline": roofline,
    }
    if only:
        unknown = sorted(set(only) - set(modules))
        if unknown:
            raise SystemExit(
                f"unknown benchmark module(s) {unknown}; "
                f"known: {sorted(modules)}"
            )
        modules = {k: v for k, v in modules.items() if k in only}

    rows: list[tuple[str, float, str]] = []
    for name, mod in modules.items():
        mod_rows = mod.benchmarks()
        _persist(name, mod_rows)
        rows.extend(mod_rows)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")


if __name__ == "__main__":
    main(only=sys.argv[1:] or None)
