"""Performance benchmarks: the paper-faithful scalar decision path vs the
beyond-paper vectorized JAX engine (§Perf of EXPERIMENTS.md).

Measured on this host (CPU): the ratio, not the absolute numbers, is the
portable result; on TPU the batched path additionally fuses with the
serving step.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.batch_decision import (
    batch_evaluate,
    batch_implied_lambda,
    batch_posterior_update,
    counterfactual_grid,
)
from repro.core.decision import speculation_decision
from repro.core.posterior import BetaPosterior
from repro.kernels import on_tpu, replay_grid_op

A_C = 0.0135
RNG = np.random.default_rng(7)


def bench_scalar_decision(n: int = 20_000) -> float:
    """us per D4 decision, paper-faithful scalar path (§6.5 pseudocode)."""
    Ps = RNG.uniform(0, 1, n)
    t0 = time.perf_counter()
    for p in Ps:
        speculation_decision(float(p), 0.5, 0.08, 500, 800, 3e-6, 15e-6, 0.8)
    return (time.perf_counter() - t0) / n * 1e6


def bench_batch_decision(n: int = 1_000_000) -> float:
    """us per decision through the jit'd batch engine."""
    Ps = RNG.uniform(0, 1, n)
    # warm up compile at the timed shape
    batch_evaluate(Ps, 0.5, 0.08, 0.8, 500, 800, 3e-6, 15e-6)[0].block_until_ready()
    t0 = time.perf_counter()
    out = batch_evaluate(Ps, 0.5, 0.08, 0.8, 500, 800, 3e-6, 15e-6)
    out[0].block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6


def bench_scalar_replay_grid(n_logs: int = 2_000) -> float:
    """us per (row x grid-point) for the §12.1 counterfactual grid, scalar."""
    lat = RNG.uniform(0.5, 3.0, n_logs)
    cost = np.full(n_logs, A_C)
    alphas = [0.0, 0.25, 0.5, 0.75, 1.0]
    lambdas = [0.005, 0.01, 0.05, 0.1]
    t0 = time.perf_counter()
    for a in alphas:
        for lam in lambdas:
            for i in range(n_logs):
                ev = 0.7 * lat[i] * lam - 0.3 * cost[i]
                _ = ev >= (1 - a) * cost[i]
    cells = len(alphas) * len(lambdas) * n_logs
    return (time.perf_counter() - t0) / cells * 1e6


def bench_batch_replay_grid(n_logs: int = 1_000_000) -> float:
    """us per (row x grid-point) through the single-XLA-call grid."""
    lat = RNG.uniform(0.5, 3.0, n_logs)
    cost = np.full(n_logs, A_C)
    alphas = [0.0, 0.25, 0.5, 0.75, 1.0]
    lambdas = [0.005, 0.01, 0.05, 0.1]
    counterfactual_grid(0.7, lat, cost, alphas, lambdas)  # warm, same shape
    t0 = time.perf_counter()
    counterfactual_grid(0.7, lat, cost, alphas, lambdas)
    cells = len(alphas) * len(lambdas) * n_logs
    return (time.perf_counter() - t0) / cells * 1e6


def bench_pallas_replay_grid(n_logs: int = 100_000) -> float:
    """us per (row x grid-point) through the fused Pallas kernel.

    On CPU the kernel runs under interpret=True (Python evaluation — a
    correctness path, not a speed path); the number that matters there is
    the jnp batch path above.  On TPU this is the fused single-launch
    sweep."""
    import jax.numpy as jnp

    P = RNG.uniform(0.1, 0.9, n_logs).astype(np.float32)
    lat = RNG.uniform(0.5, 3.0, n_logs).astype(np.float32)
    cost = np.full(n_logs, A_C, np.float32)
    alphas = np.array([0.0, 0.25, 0.5, 0.75, 1.0], np.float32)
    lambdas = np.array([0.005, 0.01, 0.05, 0.1], np.float32)
    args = [jnp.asarray(x) for x in (P, lat, cost, alphas, lambdas)]
    replay_grid_op(*args)[0].block_until_ready()  # warm at the timed shape
    t0 = time.perf_counter()
    out = replay_grid_op(*args)
    out[0].block_until_ready()
    cells = len(alphas) * len(lambdas) * n_logs
    return (time.perf_counter() - t0) / cells * 1e6


def bench_scalar_posterior(n: int = 50_000) -> float:
    post = BetaPosterior.from_prior_mean(0.5)
    outcomes = RNG.random(n) < 0.6
    t0 = time.perf_counter()
    for o in outcomes:
        post.update(bool(o))
    return (time.perf_counter() - t0) / n * 1e6


def bench_batch_posterior(edges: int = 4096, n: int = 256) -> float:
    a0 = np.full(edges, 1.0)
    b0 = np.full(edges, 1.0)
    outcomes = (RNG.random((edges, n)) < 0.6).astype(np.float32)
    batch_posterior_update(a0, b0, outcomes)  # warm, same shape
    t0 = time.perf_counter()
    batch_posterior_update(a0, b0, outcomes)
    return (time.perf_counter() - t0) / (edges * n) * 1e6


def bench_discounted_posterior(edges: int = 4096, n: int = 256) -> float:
    """Exponential-forgetting branch (sequential scan over trials)."""
    a0 = np.full(edges, 1.0)
    b0 = np.full(edges, 1.0)
    outcomes = (RNG.random((edges, n)) < 0.6).astype(np.float32)
    batch_posterior_update(a0, b0, outcomes, discount=0.99)  # warm, same shape
    t0 = time.perf_counter()
    batch_posterior_update(a0, b0, outcomes, discount=0.99)
    return (time.perf_counter() - t0) / (edges * n) * 1e6


def benchmarks() -> list[tuple[str, float, str]]:
    rows = []
    scalar = bench_scalar_decision()
    batch = bench_batch_decision()
    rows.append(("decision_scalar_paper", scalar, "per-decision"))
    rows.append(("decision_batch_jax", batch, f"speedup={scalar / batch:.0f}x"))
    sg = bench_scalar_replay_grid()
    bg = bench_batch_replay_grid()
    rows.append(("replay_grid_scalar", sg, "per-cell"))
    rows.append(("replay_grid_batch_jax", bg, f"speedup={sg / bg:.0f}x"))
    if on_tpu():
        pg = bench_pallas_replay_grid()
        rows.append(("replay_grid_pallas", pg,
                     f"fused kernel, speedup={sg / pg:.0f}x"))
    else:
        # interpret=True is a correctness path; keep the row cheap on CPU
        pg = bench_pallas_replay_grid(n_logs=2_000)
        rows.append(("replay_grid_pallas_interpret", pg, "correctness-only"))
    sp = bench_scalar_posterior()
    bp = bench_batch_posterior()
    rows.append(("posterior_scalar", sp, "per-update"))
    rows.append(("posterior_batch_jax", bp, f"speedup={sp / bp:.0f}x"))
    dp = bench_discounted_posterior()
    rows.append(("posterior_batch_discounted_jax", dp, "per-update, d=0.99"))
    return rows
