"""Appendix D synthetic numerical validation suite, seed = 20260531.

Five seeded experiments, each a direct evaluation of an equation from
paper §4–§9 at the canonical AutoReply parameters:

  D.1 decision boundary vs closed-form k_crit(alpha)
  D.2 P-threshold (EV crossings; the paper's printed P* formula is
      internally inconsistent — all three candidates are reported)
  D.3 Beta-Binomial posterior convergence (P_true = 0.62, 200 obs)
  D.4 streaming cancellation waste (10k attempts, telemetry-schema rows)
  D.5 implied-lambda recovery audit curve
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.decision import (
    critical_k,
    decision_threshold,
    expected_value,
    implied_lambda,
    p_break_even,
    p_threshold_crossing,
)
from repro.core.decision import paper_d2_p_star
from repro.core.posterior import BetaPosterior
from repro.core.pricing import TwoRateTokenCost
from repro.core.streaming import fractional_waste
from repro.core.taxonomy import DependencyType
from repro.core.telemetry import SpeculationDecision, TelemetryLog

SEED = 20260531

# AutoReply canonical parameters (DESIGN.md)
IN_TOK, OUT_TOK = 500, 800
IN_PRICE, OUT_PRICE = 3e-6, 15e-6
C_SPEC = IN_TOK * IN_PRICE + OUT_TOK * OUT_PRICE       # $0.0135
L_UPSTREAM = 0.8                                       # seconds
LAMBDA_DECLARED = 0.08                                 # USD/s
L_VALUE = L_UPSTREAM * LAMBDA_DECLARED                 # $0.064
P_STEADY = 0.62


def d1_decision_boundary() -> dict:
    """Sweep (k, alpha); empirical boundary must equal k_crit(alpha)."""
    alphas = [0.0, 0.25, 0.5, 0.75, 1.0]
    ks = list(range(1, 11))
    grid = {}
    mismatches = 0
    for a in alphas:
        kc = critical_k(L_VALUE, C_SPEC, a)
        for k in ks:
            ev = expected_value(1.0 / k, L_VALUE, C_SPEC)
            dec = "SPECULATE" if ev >= decision_threshold(a, C_SPEC) else "WAIT"
            want = "SPECULATE" if k <= kc else "WAIT"
            grid[(k, a)] = dec
            mismatches += dec != want
    return {
        "mismatches": mismatches,
        "k_crit": {a: critical_k(L_VALUE, C_SPEC, a) for a in alphas},
        "no_speculate_k6_plus": all(
            grid[(k, a)] == "WAIT" for k in range(6, 11) for a in alphas
        ),
        "grid": grid,
    }


def d2_p_threshold() -> dict:
    """EV(P) sweep at alpha=0.5 + all three closed-form candidates."""
    Ps = np.arange(0.05, 0.96, 0.01)
    evs = np.array([expected_value(p, L_VALUE, C_SPEC) for p in Ps])
    zero_crossing = float(Ps[np.argmax(evs >= 0)])
    return {
        "ev_zero_crossing_empirical": zero_crossing,
        "p_break_even_closed_form": p_break_even(L_VALUE, C_SPEC),       # 0.174
        "p_threshold_crossing_alpha05": p_threshold_crossing(L_VALUE, C_SPEC, 0.5),  # 0.261
        "paper_printed_p_star": paper_d2_p_star(L_VALUE, C_SPEC, 0.5),   # 0.191 (inconsistent)
        "ev_at_cold_start_p020": expected_value(0.20, L_VALUE, C_SPEC),
        "ev_at_post_drift_p047": expected_value(0.47, L_VALUE, C_SPEC),
        "ev_at_steady_p062": expected_value(0.62, L_VALUE, C_SPEC),
    }


def d3_posterior_convergence() -> dict:
    """Beta(1,1) prior, 200 Bernoulli(0.62) draws at the paper seed."""
    rng = np.random.default_rng(SEED)
    post = BetaPosterior.from_dependency_type(DependencyType.CONDITIONAL_OUTPUT)
    means, widths = [], []
    within_30 = None
    for i, draw in enumerate(rng.random(200) < 0.62):
        post.update(bool(draw))
        means.append(post.mean)
        lo, hi = post.credible_interval(0.95)
        widths.append(hi - lo)
        if within_30 is None and abs(post.mean - 0.62) < 0.05:
            within_30 = i + 1
    lo, hi = post.credible_interval(0.95)
    return {
        "final_mean": post.mean,
        "final_ci95": (lo, hi),
        "obs_to_enter_neighborhood": within_30,
        "ci_shrinks_monotonically": bool(widths[-1] < widths[20] < widths[5]),
    }


def d4_streaming_cancellation(n: int = 10_000) -> dict:
    """10k attempts at P=0.62; three cancellation policies.

    Every simulated decision carries the full Appendix C schema row; the
    cost summary is derived only from those rows (§C.2 discipline).
    """
    rng = np.random.default_rng(SEED)
    cm = TwoRateTokenCost(IN_PRICE, OUT_PRICE)
    success = rng.random(n) < P_STEADY
    rand_f = rng.uniform(0.10, 0.60, n)

    def simulate(policy: str) -> tuple[float, float, TelemetryLog]:
        log = TelemetryLog()
        total = 0.0
        fail_waste = []
        for i in range(n):
            ok = bool(success[i])
            if ok:
                actual = C_SPEC
            elif policy == "none":
                actual = C_SPEC
            else:
                f = 0.37 if policy == "mean" else float(rand_f[i])
                actual = fractional_waste(cm, IN_TOK, OUT_TOK, f * OUT_TOK)
            total += actual
            if not ok:
                fail_waste.append(actual)
            tokens_gen = OUT_TOK if ok or policy == "none" else int(
                (0.37 if policy == "mean" else rand_f[i]) * OUT_TOK)
            log.emit(SpeculationDecision(
                decision_id=f"{policy}-{i}", trace_id=f"trace-{i}",
                edge=("agent_a", "agent_b"), dep_type="conditional_output",
                tenant="autoreply", model_version=("frontier-default", "v1"),
                alpha=0.5, lambda_usd_per_s=LAMBDA_DECLARED, P_mean=P_STEADY,
                P_lower_bound=None, C_spec_est_usd=C_SPEC, L_est_s=L_UPSTREAM,
                input_tokens_est=IN_TOK, output_tokens_est=OUT_TOK,
                input_price=IN_PRICE, output_price=OUT_PRICE,
                EV_usd=expected_value(P_STEADY, L_VALUE, C_SPEC),
                threshold_usd=decision_threshold(0.5, C_SPEC),
                decision="SPECULATE", phase="runtime", overrode="none",
                i_hat_source="modal", uncertain_cost_flag=False, enabled=True,
                budget_remaining_usd=None, i_actual="intent",
                tier1_match=ok, tier2_match=None, tier3_accept=None,
                C_spec_actual_usd=actual,
                tokens_generated_before_cancel=tokens_gen,
                latency_actual_s=L_UPSTREAM, committed_speculative=ok,
            ))
        mean_fail = float(np.mean(fail_waste)) if fail_waste else 0.0
        return total, mean_fail, log

    total_none, fail_none, _ = simulate("none")
    total_mean, fail_mean, log_mean = simulate("mean")
    total_rand, fail_rand, _ = simulate("random")
    # §C.2: reconstruct the totals from telemetry rows alone
    total_from_rows = log_mean.cost_slo_burn()
    n_fields = len(SpeculationDecision.__dataclass_fields__)
    return {
        "total_none": total_none,          # ~$135.00
        "total_mean_cancel": total_mean,   # ~$106.6
        "total_random_cancel": total_rand,  # ~$105.7
        "per_fail_none": fail_none,        # $0.0135
        "per_fail_mean": fail_mean,        # ~$0.0059 (56% drop)
        "per_fail_drop_pct": 100 * (1 - fail_mean / fail_none),
        "total_saving_pct": 100 * (1 - total_mean / total_none),
        "telemetry_total_matches": abs(total_from_rows - total_mean) < 1e-6,
        "schema_fields": n_fields,         # 33
    }


def d5_implied_lambda() -> dict:
    """Solve the EV equation backwards for lambda over alpha* in [0, 1]."""
    alphas = np.linspace(0.0, 1.0, 21)
    lams = [implied_lambda(P_STEADY, C_SPEC, a, L_UPSTREAM) for a in alphas]
    at = lambda a: lams[int(round(a * 20))]
    return {
        "lambda_declared": LAMBDA_DECLARED,
        "implied_at_0.5": at(0.5),         # ~0.024
        "implied_at_0.9": at(0.9),         # ~0.013 — the audit-flag scenario
        "monotone_decreasing": bool(all(np.diff(lams) < 0)),
        "audit_flag_at_0.9": at(0.9) < LAMBDA_DECLARED / 3,
        "curve": dict(zip([round(a, 2) for a in alphas], lams)),
    }


def benchmarks() -> list[tuple[str, float, str]]:
    """Returns (name, us_per_call, derived) rows for benchmarks.run."""
    rows = []
    for name, fn, key in [
        ("appendix_d1_boundary", d1_decision_boundary, "no_speculate_k6_plus"),
        ("appendix_d2_p_threshold", d2_p_threshold, "p_break_even_closed_form"),
        ("appendix_d3_posterior", d3_posterior_convergence, "final_mean"),
        ("appendix_d4_cancellation", d4_streaming_cancellation, "total_saving_pct"),
        ("appendix_d5_implied_lambda", d5_implied_lambda, "implied_at_0.9"),
    ]:
        t0 = time.perf_counter()
        out = fn()
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((name, dt, f"{key}={out[key]}"))
    return rows
