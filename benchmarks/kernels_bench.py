"""Pallas hot-path kernel benchmarks: batched betaincinv + fused tick.

Two kernels, one record (BENCH_kernels.json):

* ``betaincinv`` — the tiled bracketed-Halley inverse regularized
  incomplete beta (repro.kernels.betaincinv_pallas) against the XLA
  fixed-iteration inversion in repro.core.betainc and against scipy.
  Parity (<= 1e-10 relative, the same RTOL tier-1 pins for the XLA
  path) is asserted under ``enable_x64`` *before* any timing row is
  taken.
* ``online_tick`` — the fused settle+gate+drift tick
  (repro.kernels.online_tick) through the real service dispatch
  (``OnlineDecisionService(use_fused_tick=True)``) against the default
  XLA tick, bitwise-f64 on the mean path, flag-matched with a recorded
  EV allowance on the §7.5 lower-bound path (the in-kernel betainc is
  not XLA's custom call, so 1-ULP-scale drift is expected there).

Timing sweeps the ``block_n`` tile tunable for both kernels.  On CPU
the kernels execute in Pallas interpret mode (Mosaic lowers only on
TPU), so the recorded ``backend`` / ``interpret`` fields say what was
measured: interpret-mode rows track dispatch + emulation cost and are a
correctness trajectory, not a TPU speed claim — re-measure on TPU
hardware before tuning block_n from this file (EXPERIMENTS.md
§Kernels).
"""
from __future__ import annotations

import json
import pathlib
import platform
import time

import numpy as np

from repro.core import DependencyType

ROOT = pathlib.Path(__file__).resolve().parents[1]
SEED = 20260531
RTOL = 1e-10


def _rand_abq(n: int, seed: int):
    """Log-uniform shape parameters over the tier-1 grid's span plus
    deep-tail q — the operating range of every §7.5 lower-bound call."""
    rng = np.random.default_rng(seed)
    a = np.exp(rng.uniform(np.log(0.05), np.log(150.0), n))
    b = np.exp(rng.uniform(np.log(0.05), np.log(150.0), n))
    q = np.concatenate([
        rng.uniform(1e-8, 1.0 - 1e-8, n - 2 * (n // 8)),
        np.exp(rng.uniform(np.log(1e-8), np.log(1e-3), n // 8)),
        1.0 - np.exp(rng.uniform(np.log(1e-8), np.log(1e-3), n // 8)),
    ])[:n]
    rng.shuffle(q)
    return a, b, q


def betaincinv_record(n: int = 4096, block_sweep=(256, 1024, 4096),
                      reps: int = 5, seed: int = SEED) -> dict:
    """Parity gate + block_n timing sweep for the betaincinv kernel."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from scipy import special as sp

    from repro.core.betainc import betaincinv as core_betaincinv
    from repro.kernels.betaincinv_pallas import betaincinv_kernel_call
    from repro.kernels.ops import betaincinv_op

    a, b, q = _rand_abq(n, seed)

    # --- parity first (f64, interpret mode on CPU): the kernel must sit
    # inside the same 1e-10 envelope tier-1 pins for the XLA inversion.
    with enable_x64():
        got = np.asarray(betaincinv_kernel_call(
            jnp.asarray(a), jnp.asarray(b), jnp.asarray(q), interpret=True))
        ref_core = np.asarray(core_betaincinv(
            jnp.asarray(a), jnp.asarray(b), jnp.asarray(q)))
        ref_scipy = sp.betaincinv(a, b, q)

        def _max_rel(ref):
            denom = np.maximum(np.abs(ref), 1e-300)
            rel = np.abs(got - ref) / denom
            bad = rel > RTOL
            if bad.any():
                # scipy's own ppf carries >1e-10 error at a handful of
                # small-shape points; accept those via the round-trip
                # |I(a,b,x) - q| <= 1e-9 * q (same fallback tier-1 uses)
                rt = np.abs(sp.betainc(a[bad], b[bad], got[bad]) - q[bad])
                if (rt > 1e-9 * np.maximum(q[bad], 1e-300)).any():
                    worst = int(np.argmax(rel))
                    raise AssertionError(
                        f"betaincinv kernel parity broke: rel "
                        f"{rel[worst]:.3e} at a={a[worst]} b={b[worst]} "
                        f"q={q[worst]}")
                rel = np.where(bad, 0.0, rel)
            return float(rel.max())

        max_rel_core = _max_rel(ref_core)
        max_rel_scipy = _max_rel(ref_scipy)

    # --- then timing (working dtype) through the dispatch op, per tile
    # size.  Reference row: the jitted XLA inversion on the same batch.
    aj, bj, qj = jnp.asarray(a), jnp.asarray(b), jnp.asarray(q)
    core_jit = jax.jit(core_betaincinv)

    def _time(fn):
        fn().block_until_ready()                      # warm the executable
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(reps):
                out = fn()
            out.block_until_ready()
            best = min(best, (time.perf_counter() - t0) / reps)
        return best * 1e6

    sweep = [{"block_n": int(bn),
              "us_per_call": _time(lambda bn=bn: betaincinv_op(
                  aj, bj, qj, block_n=int(bn)))}
             for bn in block_sweep]
    ref_us = _time(lambda: core_jit(aj, bj, qj))

    return {
        "n": n,
        "parity": {"max_rel_vs_core": max_rel_core,
                   "max_rel_vs_scipy": max_rel_scipy,
                   "asserted_rtol": RTOL},
        "sweep": sweep,
        "reference_us_per_call": ref_us,
    }


def _build_service(n_rows: int, **kw):
    from repro.core.online import OnlineDecisionService

    svc = OnlineDecisionService(**kw)
    for i in range(n_rows):
        svc.register_edge(("classifier", f"drafter{i}"),
                          dep_type=DependencyType.ROUTER_K_WAY,
                          k=2 + i % 7, gamma=0.1,
                          discount=(1.0, 0.97)[i % 2])
    return svc


def _tick_blocks(n_rows: int, batch: int, settles: int, seed: int, dtype):
    """One packed request block + settle bucket (tail -1 sentinels)."""
    rng = np.random.default_rng(seed)
    row = np.full(batch, -1, np.int32)
    nb = max(1, batch - batch // 8)
    row[:nb] = rng.integers(0, n_rows, nb)
    reqs = np.zeros((batch, 7), dtype)
    reqs[:, 0] = rng.uniform(0.0, 1.0, batch)
    reqs[:, 1] = rng.uniform(1e-3, 0.5, batch)
    reqs[:, 2] = rng.uniform(0.05, 4.0, batch)
    reqs[:, 3], reqs[:, 4] = 32, 160
    reqs[:, 5], reqs[:, 6] = 3e-6, 15e-6
    out_row = np.full(settles, -1, np.int32)
    ns = max(1, settles - settles // 8)
    out_row[:ns] = rng.integers(0, max(n_rows // 2, 1), ns)
    out_x = np.zeros(settles, dtype)
    out_x[:ns] = rng.integers(0, 2, ns).astype(dtype)
    return row, reqs, out_row, out_x


def online_tick_record(n_rows: int = 256, batch: int = 128,
                       settles: int = 64, block_sweep=(64, 256, 1024),
                       reps: int = 20, ticks: int = 4,
                       seed: int = SEED) -> dict:
    """Parity gate + block_n timing sweep for the fused tick kernel,
    driven through the real ``OnlineDecisionService`` dispatch."""
    import jax
    from jax.experimental import enable_x64

    # --- parity first (f64): fused vs default service, same tick
    # stream (requests + settles + drift checks), bitwise everywhere on
    # the mean path; lower-bound ticks must flag-match with the EV drift
    # recorded (in-kernel betainc vs XLA's betainc custom call).
    lb_max_rel = 0.0
    with enable_x64():
        svc0 = _build_service(n_rows)
        svc1 = _build_service(n_rows, use_fused_tick=True)
        for t in range(ticks):
            row, reqs, out_row, out_x = _tick_blocks(
                n_rows, batch, settles, seed + t, np.float64)
            d0 = svc0.tick_packed(row, reqs.copy(), out_row=out_row,
                                  out_x=out_x, check_drift=(t % 2 == 1))
            d1 = svc1.tick_packed(row, reqs.copy(), out_row=out_row,
                                  out_x=out_x, check_drift=(t % 2 == 1))
            for f in ("speculate", "EV_usd", "threshold_usd", "margin_usd"):
                if not np.array_equal(getattr(d0, f), getattr(d1, f)):
                    raise AssertionError(
                        f"fused tick parity broke: {f} at tick {t}")
        if not (np.array_equal(svc0.posterior_snapshot(),
                               svc1.posterior_snapshot())
                and np.array_equal(np.asarray(svc0._tel),
                                   np.asarray(svc1._tel))):
            raise AssertionError(
                "fused tick parity broke: posterior/telemetry state")
        # §7.5 lower-bound tier
        row, reqs, out_row, out_x = _tick_blocks(
            n_rows, batch, settles, seed + ticks, np.float64)
        d0 = svc0.tick_packed(row, reqs.copy(), out_row=out_row,
                              out_x=out_x, use_lower_bound=True)
        d1 = svc1.tick_packed(row, reqs.copy(), out_row=out_row,
                              out_x=out_x, use_lower_bound=True)
        if not np.array_equal(d0.speculate, d1.speculate):
            raise AssertionError("fused tick lower-bound flags diverged")
        denom = np.maximum(np.abs(d0.EV_usd), 1e-300)
        lb_max_rel = float(np.max(np.abs(d0.EV_usd - d1.EV_usd) / denom))
        if lb_max_rel > 1e-9:
            raise AssertionError(
                f"fused tick lower-bound EV drifted: {lb_max_rel:.3e}")

    # --- then timing (working dtype): per-tick wall time with the
    # honest per-tick host round-trip, best-of-rounds (2-core container).
    fdtype = np.dtype("float64" if jax.config.jax_enable_x64
                      else "float32")
    row, reqs, out_row, out_x = _tick_blocks(
        n_rows, batch, settles, seed, fdtype)

    def _time_service(svc):
        svc.tick_packed(row, reqs, out_row=out_row, out_x=out_x)
        svc.tick_packed(row, reqs, out_row=out_row, out_x=out_x)
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(reps):
                d = svc.tick_packed(row, reqs, out_row=out_row, out_x=out_x)
                d.speculate                       # per-tick host sync
            best = min(best, (time.perf_counter() - t0) / reps)
        return best * 1e6

    sweep = [{"block_n": int(bn),
              "us_per_tick": _time_service(_build_service(
                  n_rows, use_fused_tick=True, fused_block_n=int(bn)))}
             for bn in block_sweep]
    ref_us = _time_service(_build_service(n_rows))

    return {
        "rows": n_rows,
        "batch": batch,
        "settles": settles,
        "parity": {"mean_path_bitwise_f64": True,
                   "lower_bound_max_rel": lb_max_rel},
        "sweep": sweep,
        "reference_us_per_tick": ref_us,
    }


def kernels_record(bii_n: int = 4096, bii_sweep=(256, 1024, 4096),
                   tick_rows: int = 256, tick_batch: int = 128,
                   tick_settles: int = 64, tick_sweep=(64, 256, 1024),
                   reps: int = 10, seed: int = SEED) -> dict:
    """The full BENCH_kernels.json record (parity before every timing)."""
    import jax

    from repro.kernels.ops import _interpret

    interpret = _interpret()
    return {
        "benchmark": "pallas_hot_path_kernels",
        "backend": jax.default_backend(),
        "interpret": interpret,
        "betaincinv": betaincinv_record(bii_n, bii_sweep, reps=max(3, reps // 2),
                                        seed=seed),
        "online_tick": online_tick_record(tick_rows, tick_batch, tick_settles,
                                          tick_sweep, reps=reps, seed=seed),
    }


def smoke() -> dict:
    """Tiny-shape parity + schema gate (no timing claims, no writes).

    Every parity assertion in the full record still executes — the
    betaincinv <=1e-10 envelope and the fused tick's bitwise-f64 mean
    path — at shapes small enough for tier-1; timing rows exist only so
    the schema validator sees the real record shape."""
    return kernels_record(bii_n=96, bii_sweep=(16, 96), tick_rows=24,
                          tick_batch=8, tick_settles=8, tick_sweep=(8, 32),
                          reps=2)


def benchmarks() -> list[tuple[str, float, str]]:
    """Full record: persists BENCH_kernels.json, returns summary rows."""
    rec = kernels_record()
    rec["host"] = platform.machine()
    rec["unix_time"] = int(time.time())
    (ROOT / "BENCH_kernels.json").write_text(
        json.dumps(rec, indent=2) + "\n")

    bii = rec["betaincinv"]
    best_bii = min(bii["sweep"], key=lambda r: r["us_per_call"])
    tick = rec["online_tick"]
    best_tick = min(tick["sweep"], key=lambda r: r["us_per_tick"])
    mode = "interpret" if rec["interpret"] else "native"
    return [
        ("kernel_betaincinv", best_bii["us_per_call"],
         f"n={bii['n']} block_n={best_bii['block_n']} {mode} "
         f"xla_ref={bii['reference_us_per_call']:.1f}us "
         f"rel<={bii['parity']['max_rel_vs_core']:.1e}"),
        ("kernel_online_tick", best_tick["us_per_tick"],
         f"rows={tick['rows']} B={tick['batch']} "
         f"block_n={best_tick['block_n']} {mode} "
         f"xla_ref={tick['reference_us_per_tick']:.1f}us bitwise"),
    ]
