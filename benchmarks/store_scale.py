"""Fleet-scale benchmark of the paged hierarchical posterior store.

tests/test_store.py pins the store's contracts at toy sizes; this module
exercises them at the scale §14.3 actually asks about — **a million
logical (tenant, edge) rows behind a few thousand device-resident
slots** — and records what an operator would ask of the subsystem:

* registration throughput (amortized-O(1) host insert, no device work),
* decide throughput under worst-case paging churn (every tick faults a
  random batch across the full logical range, LRU-spilling victims),
* memory per logical row, host SoA vs device table,
* the zero-recompile guarantee under capacity-doubling insert/evict
  churn, asserted via jit compile-cache sizes,
* the empirical-Bayes cold-start recovery curve: a cold row born from
  its bucket's pooled hyperprior vs the fixed taxonomy prior against a
  planted per-bucket p*.

The repo's standing discipline applies: **parity before timing**.
Under ``enable_x64`` a paged store must answer ticks bitwise-f64 equal
to the dense identity-mode service on the same rows, and the 1M-row
store's decisions must be bitwise-f64 equal to scalar
``decision.evaluate`` over the composed snapshot — only then is
anything timed.

Everything is persisted to ``BENCH_store.json`` (``smoke()`` returns
the same record shape at tiny sizes, makes no timing claims, and never
touches the file).
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

BENCH_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_store.json"

SEED = 0


# --------------------------------------------------------------------------
# registry + request helpers
# --------------------------------------------------------------------------
def _dep_mix():
    from repro.core.taxonomy import DependencyType

    return [
        (DependencyType.ALWAYS_PRODUCES_OUTPUT, None),
        (DependencyType.CONDITIONAL_OUTPUT, None),
        (DependencyType.LIST_OUTPUT_VARIABLE_LENGTH, None),
        (DependencyType.ROUTER_K_WAY, 2),
        (DependencyType.ROUTER_K_WAY, 3),
    ]


def _register_mixed(svc, n: int) -> None:
    """The tests' registry mix (router k spread, discounts, floors) so
    parity runs cover heterogeneous row configs."""
    from repro.core.taxonomy import DependencyType

    for i in range(n):
        svc.register_edge(
            ("u", f"v{i}"),
            dep_type=DependencyType.ROUTER_K_WAY,
            k=2 + i % 5,
            discount=(0.95 if i % 3 == 0 else 1.0),
            floor_C_spec_usd=0.01,
            floor_L_value_usd=0.05,
        )


def _requests(rng, B, rows):
    return dict(
        rows=rng.choice(rows, B),
        alpha=rng.uniform(0, 1, B),
        lam=rng.uniform(1e-4, 0.5, B),
        lat=rng.uniform(0.01, 5.0, B),
        in_tok=rng.integers(1, 2000, B).astype(float),
        out_tok=rng.uniform(1, 2000, B),
        in_price=rng.uniform(1e-8, 1e-4, B),
        out_price=rng.uniform(1e-8, 1e-4, B),
    )


def _tick(svc, req, **kw):
    return svc.tick(
        req["rows"], alpha=req["alpha"], lambda_usd_per_s=req["lam"],
        latency_s=req["lat"], input_tokens=req["in_tok"],
        output_tokens=req["out_tok"], input_price=req["in_price"],
        output_price=req["out_price"], **kw)


def _scalar_ref(snap, req, j, row):
    from repro.core.decision import DecisionInputs, evaluate
    from repro.core.posterior import BetaPosterior

    a, b = snap[row]
    return evaluate(DecisionInputs(
        P=BetaPosterior(alpha=float(a), beta=float(b)).mean,
        alpha=float(req["alpha"][j]),
        lambda_usd_per_s=float(req["lam"][j]),
        latency_seconds=float(req["lat"][j]),
        input_tokens=int(req["in_tok"][j]),
        output_tokens=float(req["out_tok"][j]),
        input_price=float(req["in_price"][j]),
        output_price=float(req["out_price"][j]),
    ))


# --------------------------------------------------------------------------
# parity gates (run before any timing — repo discipline)
# --------------------------------------------------------------------------
def dense_paged_parity(*, n_rows: int = 40, resident_rows: int = 8,
                       ticks: int = 12, batch: int = 6,
                       n_outcomes: int = 4, seed: int = 7) -> dict:
    """A paged store holding ``resident_rows`` of ``n_rows`` on device —
    ticks cycling every row force constant LRU spill / fault-in — must
    answer every decision, settle every outcome, and run every drift
    step bitwise-f64 identical to the dense identity-mode service."""
    from jax.experimental import enable_x64

    from repro.core.online import OnlineDecisionService

    with enable_x64():
        dense = OnlineDecisionService(use_lower_bound=True)
        paged = OnlineDecisionService(use_lower_bound=True,
                                      resident_rows=resident_rows,
                                      min_rows=resident_rows)
        _register_mixed(dense, n_rows)
        _register_mixed(paged, n_rows)
        rng_seq = np.random.default_rng(seed)
        for t in range(ticks):
            rows = np.arange((t * 7) % n_rows,
                             (t * 7) % n_rows + batch) % n_rows
            req = _requests(np.random.default_rng(100 + t), batch, rows)
            outcomes = [(int(r), bool(rng_seq.integers(2)))
                        for r in rng_seq.choice(rows, n_outcomes)]
            dd = _tick(dense, req, outcomes=outcomes, check_drift=True)
            dp = _tick(paged, req, outcomes=outcomes, check_drift=True)
            for field in ("speculate", "EV_usd", "threshold_usd",
                          "margin_usd", "P_used"):
                if not np.array_equal(getattr(dd, field),
                                      getattr(dp, field)):
                    raise AssertionError(
                        f"paged != dense on {field} at tick {t}")
            if not np.array_equal(dd.drift_triggered[:n_rows],
                                  dp.drift_triggered[:n_rows]):
                raise AssertionError(f"paged != dense drift at tick {t}")
        for name, a, b in (
            ("posterior_snapshot", dense.posterior_snapshot(),
             paged.posterior_snapshot()),
            ("breach_runs", dense.breach_runs(), paged.breach_runs()),
            ("enabled_snapshot", dense.enabled_snapshot(),
             paged.enabled_snapshot()),
        ):
            if not np.array_equal(a, b):
                raise AssertionError(f"paged != dense {name} after churn")
        if not paged.store.stats["spills"]:
            raise AssertionError("parity churn never spilled a row")
    return {
        "rows": n_rows,
        "resident_rows": resident_rows,
        "ticks": ticks,
        "spills": paged.store.stats["spills"],
        "fault_ins": paged.store.stats["fault_ins"],
    }


def scalar_parity(svc, rows: np.ndarray, *, group: int,
                  seed: int = SEED) -> int:
    """Assert the store-backed service's batched decisions are bitwise
    -f64 equal to scalar ``decision.evaluate`` over the composed
    snapshot (device + shelf + unborn tiers).  Returns rows checked."""
    snap = svc.posterior_snapshot()
    checked = 0
    for start in range(0, len(rows), group):
        chunk = np.asarray(rows[start:start + group])
        req = _requests(np.random.default_rng(seed + start), len(chunk),
                        chunk)
        req["rows"] = chunk
        d = _tick(svc, req)
        for j, i in enumerate(chunk):
            ref = _scalar_ref(snap, req, j, int(i))
            if not (d.EV_usd[j] == ref.EV_usd
                    and d.threshold_usd[j] == ref.threshold_usd
                    and d.P_used[j] == ref.P_used):
                raise AssertionError(
                    f"paged tick != scalar evaluate on logical row {i}")
            checked += 1
    return checked


# --------------------------------------------------------------------------
# zero recompiles across capacity-doubling insert/evict churn
# --------------------------------------------------------------------------
def zero_recompile_churn(*, base_rows: int = 256, resident_rows: int = 64,
                         steps: int = 120, per_step: int = 8,
                         batch: int = 16, evict_every: int = 3,
                         seed: int = 11) -> dict:
    """Insert/evict churn that doubles the logical registry capacity
    multiple times must leave every jit cache exactly where warm-up put
    it: the physical table shape is fixed, so growth is host-only.
    Asserted via compile-cache sizes (the acceptance mechanism)."""
    from jax.experimental import enable_x64

    from repro.core import online as online_mod
    from repro.core.online import OnlineDecisionService
    from repro.core.store import _bucket, _gather_rows, _scatter_rows
    from repro.core.taxonomy import DependencyType

    with enable_x64():
        svc = OnlineDecisionService(resident_rows=resident_rows,
                                    min_rows=resident_rows)
        # warm-up faults k = K, K/2, ..., 1 fresh rows through a full
        # table, so the registry needs resident_rows + (2K - 1) rows
        K = _bucket(max(batch, resident_rows))
        total0 = max(base_rows, resident_rows + 2 * K)
        _register_mixed(svc, total0)
        rng = np.random.default_rng(seed)
        _tick(svc, _requests(rng, batch, np.arange(batch)),
              outcomes=[(0, True)], check_drift=True)   # tick executables
        # warm every power-of-two scatter/gather pad bucket the churn can
        # reach: filling the table then faulting k fresh rows compiles
        # both the k-lane fault-in scatter and the k-victim spill gather
        svc.store.ensure_resident(np.arange(resident_rows))
        cursor = resident_rows
        k = K
        while k >= 1:
            svc.store.ensure_resident(np.arange(cursor, cursor + k))
            cursor += k
            k //= 2
        caches = lambda: (                               # noqa: E731
            online_mod._tick._cache_size(),
            _scatter_rows._cache_size(),
            _gather_rows._cache_size(),
        )
        warm = caches()
        cap0 = _bucket(max(total0, svc.store.min_rows, 16))
        live = list(range(total0))
        next_edge = total0
        evictions = 0
        for step in range(steps):
            for _ in range(per_step):
                live.append(svc.register_edge(
                    ("w", f"x{next_edge}"),
                    dep_type=DependencyType.ALWAYS_PRODUCES_OUTPUT))
                next_edge += 1
            if step % evict_every == 0:
                svc.store.evict_row(live.pop(int(rng.integers(len(live)))))
                evictions += 1
            rows = rng.choice(np.asarray(live), batch, replace=False)
            _tick(svc, _requests(rng, batch, rows),
                  outcomes=[(int(rows[0]), True)], check_drift=True)
        after = caches()
        doublings = (_bucket(svc.store.n_rows).bit_length()
                     - cap0.bit_length())
        if after != warm:
            raise AssertionError(
                f"churn recompiled: caches {warm} -> {after}")
        if svc.store.stats["rebuilds"] != 1:
            raise AssertionError(
                f"physical table rebuilt {svc.store.stats['rebuilds']}x")
        if doublings < 1:
            raise AssertionError("churn never doubled the logical capacity")
    return {
        "churn_steps": steps,
        "registered_per_step": per_step,
        "evictions": evictions,
        "logical_rows_end": svc.store.n_rows,
        "host_capacity_doublings": doublings,
        "physical_capacity": svc.store.capacity,
        "rebuilds": svc.store.stats["rebuilds"],
        "caches": {"tick": warm[0], "scatter": warm[1], "gather": warm[2]},
        "asserted": True,
    }


# --------------------------------------------------------------------------
# empirical-Bayes cold-start recovery curve (planted per-bucket p*)
# --------------------------------------------------------------------------
def cold_start_curve(*, p_star: float = 0.3, n_warm: int = 64,
                     trials: int = 200, seed: int = SEED,
                     checkpoints=(0, 1, 2, 5, 10, 20, 50, 100, 200,
                                  500)) -> dict:
    """Warm rows in one taxonomy bucket each see ``trials`` Bernoulli(p*)
    outcomes; after the jit'd EB fit a brand-new row is born from the
    bucket's pooled hyperprior.  The curve tracks |posterior mean - p*|
    for the pooled-born row vs a fixed-taxonomy-prior twin over the same
    outcome stream — pooled must start strictly tighter and both must
    converge (shrinkage fades under conjugate evidence)."""
    from jax.experimental import enable_x64

    from repro.core.posterior import BetaPosterior
    from repro.core.store import PosteriorStore
    from repro.core.taxonomy import DependencyType, prior_params

    dep = DependencyType.ALWAYS_PRODUCES_OUTPUT
    with enable_x64():
        store = PosteriorStore(resident_rows=256)
        rng = np.random.default_rng(seed)
        for i in range(n_warm):
            store.register(("op", f"w{i}"), dep_type=dep)
        store.device_tables("float64")
        store.ensure_resident(np.arange(n_warm))
        a0, b0 = prior_params(dep)
        succ = rng.binomial(trials, p_star, n_warm)
        store.set_rows(
            np.arange(n_warm),
            np.stack([a0 + succ, b0 + (trials - succ)], 1).astype(float))
        store.fit_hyperpriors(min_evidence=5.0, strength_cap=200.0)
        label = PosteriorStore.bucket_label(dep)
        hp = store.hyperpriors[label]
        cold = store.register(("op", "cold"), dep_type=dep)
        born = store.rows_snapshot([cold])[0]
        if tuple(born) != (hp.alpha, hp.beta):
            raise AssertionError("cold row not born from the pooled prior")
    pooled = BetaPosterior(alpha=hp.alpha, beta=hp.beta)
    fixed = BetaPosterior(alpha=a0, beta=b0)
    outcomes = np.random.default_rng(seed + 1).random(
        max(checkpoints)) < p_star
    curve, n_obs = [], 0
    for cp in sorted(checkpoints):
        while n_obs < cp:
            pooled.update(bool(outcomes[n_obs]))
            fixed.update(bool(outcomes[n_obs]))
            n_obs += 1
        curve.append({
            "n_obs": cp,
            "pooled_abs_err": round(abs(pooled.mean - p_star), 6),
            "fixed_abs_err": round(abs(fixed.mean - p_star), 6),
        })
    if not curve[0]["pooled_abs_err"] < curve[0]["fixed_abs_err"]:
        raise AssertionError(
            f"pooled cold start not tighter: {curve[0]}")
    if abs(pooled.mean - fixed.mean) > 0.05:
        raise AssertionError("pooled and fixed posteriors did not converge")
    return {
        "p_star": p_star,
        "bucket": label,
        "warm_rows": n_warm,
        "trials_per_warm_row": trials,
        "pooled_prior": {
            "alpha": round(hp.alpha, 6), "beta": round(hp.beta, 6),
            "mean": round(hp.mean, 6), "strength": round(hp.strength, 6),
            "fitted_rows": hp.n_rows,
        },
        "fixed_prior": {
            "alpha": a0, "beta": b0, "mean": round(a0 / (a0 + b0), 6),
        },
        "curve": curve,
        "pooled_tighter_at_birth": True,
    }


# --------------------------------------------------------------------------
# the million-row record
# --------------------------------------------------------------------------
def store_record(*, logical_rows: int = 1_000_000,
                 resident_rows: int = 4096, batch: int = 256,
                 n_outcomes: int = 32, timed_ticks: int = 32,
                 parity_sample: int = 256, seed: int = SEED,
                 write: bool = True) -> dict:
    """Parity gates → zero-recompile churn → cold-start curve → timed
    1M-row register + paged decide churn → BENCH_store.json."""
    from jax.experimental import enable_x64

    from repro.core.online import OnlineDecisionService

    parity = dense_paged_parity(n_rows=256, resident_rows=32, ticks=20,
                                batch=32, n_outcomes=8)
    zero_recompile = zero_recompile_churn()
    cold_start = cold_start_curve()

    with enable_x64():
        svc = OnlineDecisionService(resident_rows=resident_rows,
                                    min_rows=256)
        mix = _dep_mix()
        t0 = time.perf_counter()
        for i in range(logical_rows):
            dep, k = mix[i % len(mix)]
            svc.register_edge(("op", f"e{i}"), tenant=f"t{i & 1023}",
                              dep_type=dep, k=k)
        register_wall = time.perf_counter() - t0

        # fill + steady-state the resident set so every later tick pays
        # the worst case: a full batch of faults each spilling a victim
        rng = np.random.default_rng(seed + 2)

        def churn_tick(out: bool):
            rows = rng.choice(logical_rows, batch, replace=False)
            req = _requests(rng, batch, rows)
            req["rows"] = rows
            outcomes = ([(int(rows[j]), bool(j % 2))
                         for j in range(n_outcomes)] if out else None)
            return _tick(svc, req, outcomes=outcomes)

        while svc.store.n_resident < svc.store.capacity:
            churn_tick(True).speculate
        for _ in range(2):                      # warm the steady state
            churn_tick(True).speculate

        # acceptance gate at scale: the LRU-paged 1M-row store answers
        # batched ticks bitwise-f64 equal to scalar decision.evaluate
        sample = np.random.default_rng(seed + 3).choice(
            logical_rows, parity_sample, replace=False)
        rows_checked = scalar_parity(svc, sample, group=batch, seed=seed)

        spills0 = svc.store.stats["spills"]
        faults0 = svc.store.stats["fault_ins"]
        t0 = time.perf_counter()
        for _ in range(timed_ticks):
            churn_tick(True).speculate          # one host sync per tick
        decide_wall = time.perf_counter() - t0
        memory = svc.store.memory_stats()
        decide = {
            "ticks": timed_ticks,
            "batch": batch,
            "outcomes_per_tick": n_outcomes,
            "wall_s": round(decide_wall, 4),
            "us_per_decision": round(
                decide_wall / (timed_ticks * batch) * 1e6, 3),
            "fault_ins": svc.store.stats["fault_ins"] - faults0,
            "spills": svc.store.stats["spills"] - spills0,
        }

    record = {
        "benchmark": "posterior_store_scale",
        "seed": seed,
        "logical_rows": logical_rows,
        "resident_capacity": memory["capacity"],
        "decisions_per_s": round(timed_ticks * batch / decide_wall, 2),
        "parity": {
            "paged_vs_dense_bitwise_f64": True,
            "paged_vs_scalar_bitwise_f64": True,
            "rows_checked": rows_checked,
            "dense_paged": parity,
        },
        "zero_recompile": zero_recompile,
        "register": {
            "rows": logical_rows,
            "wall_s": round(register_wall, 4),
            "us_per_row": round(register_wall / logical_rows * 1e6, 3),
        },
        "decide": decide,
        "memory": memory,
        "cold_start": cold_start,
    }
    if write:
        BENCH_PATH.write_text(json.dumps(record, indent=2) + "\n")
    return record


def smoke() -> dict:
    """The --smoke gate: every parity / zero-recompile / cold-start
    assertion at tiny sizes (the same shapes tests/test_store.py
    compiles, so tier-1 shares the jit cache), no timing claims, nothing
    written.  The record keeps the full BENCH_store.json shape so schema
    drift breaks tier-1."""
    from jax.experimental import enable_x64

    from repro.core.online import OnlineDecisionService

    parity = dense_paged_parity()                # test_store's exact shapes
    zero_recompile = zero_recompile_churn(
        base_rows=16, resident_rows=8, steps=20, per_step=3, batch=4,
        evict_every=4)
    cold_start = cold_start_curve(n_warm=16, trials=80,
                                  checkpoints=(0, 1, 5, 20, 100))

    with enable_x64():
        svc = OnlineDecisionService(resident_rows=4, min_rows=4)
        _register_mixed(svc, 16)
        rng = np.random.default_rng(3)
        for start in range(0, 16, 4):           # spill every row once
            _tick(svc, _requests(rng, 4, np.arange(start, start + 4)),
                  outcomes=[(start, True), (start + 1, False)])
        rows_checked = scalar_parity(svc, np.arange(16), group=4, seed=40)
        memory = svc.store.memory_stats()
        stats = dict(svc.store.stats)

    return {
        "benchmark": "posterior_store_scale",
        "seed": SEED,
        "logical_rows": 16,
        "resident_capacity": memory["capacity"],
        "decisions_per_s": 0.0,                  # no timing claims in smoke
        "parity": {
            "paged_vs_dense_bitwise_f64": True,
            "paged_vs_scalar_bitwise_f64": True,
            "rows_checked": rows_checked,
            "dense_paged": parity,
        },
        "zero_recompile": zero_recompile,
        "register": {"rows": 16, "wall_s": 0.0, "us_per_row": 0.0},
        "decide": {
            "ticks": 8, "batch": 4, "outcomes_per_tick": 2, "wall_s": 0.0,
            "us_per_decision": 0.0,
            "fault_ins": stats["fault_ins"], "spills": stats["spills"],
        },
        "memory": memory,
        "cold_start": cold_start,
    }


def benchmarks() -> list[tuple[str, float, str]]:
    rec = store_record()
    zr = rec["zero_recompile"]
    return [(
        "store_paged_decide_1M",
        rec["decide"]["us_per_decision"],
        (f"{rec['logical_rows']} logical rows on "
         f"{rec['resident_capacity']} resident slots | "
         f"register {rec['register']['us_per_row']}us/row | "
         f"decide {rec['decisions_per_s']:.0f}/s under full-fault churn | "
         f"0 recompiles over {zr['host_capacity_doublings']} capacity "
         f"doublings"),
    )]


if __name__ == "__main__":
    print(json.dumps(store_record(), indent=2))
